//! MVM-based GP regression (paper §2.2) over structured kernel operators.
//!
//! One model drives the headline scalable methods:
//! - **SKIP** (§3.1/§5): d per-dimension 1-D SKI operators merged by the
//!   SKIP tree — O(dn + dm log m) per MVM after the cached decomposition.
//! - **KISS-GP** (§2.3/§5): a d-dimensional Kronecker-grid SKI operator —
//!   O(4ᵈn + d mᵈ log m) per MVM, the exponential baseline.
//! - **Sparse-grid KISS-GP** (`GridSpec::Sparse`): the combination
//!   technique of Yadav, Sheldon & Musco (2023) replaces the dense mᵈ
//!   tensor grid with a signed sum of anisotropic Kronecker terms whose
//!   point count grows near-linearly in d — the Kronecker path without
//!   its d ≲ 5 cap.
//!
//! The inducing grid is configured by [`crate::grid::GridSpec`] and built
//! through the [`crate::grid::InducingGrid`] trait, so every grid
//! consumer (operator construction, the predictive stencil cache, the
//! serving snapshot) shares one fitting/stencil/budget implementation.
//!
//! Inference uses *preconditioned* CG for solves (block-CG when several
//! right-hand sides ride together, as in the gradient's y-solve +
//! Hutchinson probes): `cfg.cg.precond` selects a pivoted-Cholesky /
//! Jacobi / identity preconditioner built once per operator with the
//! exact noise shift σ_n², and with `cfg.policy.warm_start` successive
//! y-solves seed from the previous solution (see `docs/SOLVERS.md`).
//! The deployment-facing solver knobs (preconditioner, precision, solve
//! space, warm starts) arrive bundled as a
//! [`crate::solvers::SolverPolicy`] — the same struct the streaming and
//! snapshot configs embed, parsed once from the CLI.
//! Log-determinants use batched-probe SLQ. Training
//! maximizes Eq. (3) with ADAM; gradients are analytic in (σ_f², σ_n²)
//! and central finite differences with **common random numbers** in log ℓ
//! (the same probe/seed is used at ℓ·e^{±h}, so the stochastic parts of
//! the two MLL estimates cancel in the difference).

use super::adam::Adam;
use super::hypers::GpHypers;
use crate::grid::{build_grid, grid_ski_operator, grid_ski_parts, Grid1d, GridSpec};
use crate::kernels::{deriv_layout, ProductKernel};
use crate::linalg::{dot, Matrix};
use crate::operators::{
    AffineOp, ArcOp, ContractionBackend, KroneckerSkiOp, LinearOp, NativeBackend, SkiOp,
    SkipComponent, SkipOp, SumOp,
};
use crate::serve::cache::{build_grad_cache, PredictCache};
use crate::solvers::{
    block_cg_solve_with, build_preconditioner, cg_solve_with, grid_cg_solve,
    slq_logdet, CgConfig, GridSystem, Preconditioner, SlqConfig, SolverPolicy,
};
use crate::util::Rng;
use crate::{Error, Result};
use std::borrow::Cow;
use std::sync::{Arc, Mutex};

/// Largest stored grid (Σ_t Π m_k cells across terms) the predictive
/// stencil cache may occupy; beyond it (high d on a dense spec)
/// prediction falls back to the dense cross-covariance path. 2²¹ cells
/// ≈ 16 MB of mean cache. Sparse specs essentially always fit.
const PREDICT_CACHE_MAX_CELLS: usize = 1 << 21;

/// Largest dense tensor grid the Kronecker operator will materialize;
/// beyond it the build refuses with a typed error pointing at
/// [`GridSpec::Sparse`] (historically this path silently required
/// d ≲ 5 — now the cap is explicit and the sparse spec removes it).
const KRON_MAX_CELLS: usize = 1 << 24;

/// Which structured operator backs the model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MvmVariant {
    /// SKIP: product of per-dimension 1-D SKI kernels (the paper's method).
    Skip,
    /// KISS-GP: Kronecker multi-dimensional grid. Dense specs are capped
    /// by [`KRON_MAX_CELLS`]; `GridSpec::Sparse` lifts the cap.
    Kiss,
}

// `SolveSpace` historically lived here; it moved to `crate::solvers`
// with the rest of the solver policy, and this re-export keeps the
// long-standing `skip_gp::gp::SolveSpace` path working.
pub use crate::solvers::SolveSpace;

/// Configuration for MVM-based inference.
#[derive(Clone, Debug)]
pub struct MvmGpConfig {
    pub variant: MvmVariant,
    /// Inducing-grid specification (uniform per-dimension m, explicit
    /// per-dimension sizes, or a combination-technique sparse grid).
    pub grid: GridSpec,
    /// Lanczos rank r for SKIP decompositions during *training* (noisy
    /// gradients tolerate truncation error).
    pub rank: usize,
    /// Lanczos rank for the final predictive solve. The solve
    /// `α = K̂⁻¹y` amplifies operator error by roughly the condition
    /// number, so the cached α is computed at higher rank — matching the
    /// paper's "maximum number of Lanczos iterations to 100" (§4).
    pub refresh_rank: usize,
    /// CG budget — including [`CgConfig::precond`], which selects the
    /// preconditioner every covariance solve (training, refresh,
    /// variance) builds per operator (`--precond rank:K|jacobi|none` on
    /// the CLI; see `docs/SOLVERS.md` for tuning).
    pub cg: CgConfig,
    pub slq: SlqConfig,
    /// The deployment-facing solver knobs — preconditioner, precision,
    /// solve space, warm starts — shared with the streaming and snapshot
    /// configs. The preconditioner/precision components are folded into
    /// [`CgConfig`] by [`MvmGp::new`] (non-default policy wins, a
    /// directly-set `cg` field survives a default policy), so every
    /// solve this model issues — training, refresh, variance, grid
    /// space — routes through one switch.
    pub policy: SolverPolicy,
    /// Base seed for probe vectors (common-random-numbers gradients).
    pub seed: u64,
}

impl Default for MvmGpConfig {
    fn default() -> Self {
        MvmGpConfig {
            variant: MvmVariant::Skip,
            grid: GridSpec::Uniform(100),
            rank: 30,
            refresh_rank: 100,
            cg: CgConfig { max_iters: 100, tol: 1e-5, ..CgConfig::default() },
            slq: SlqConfig { num_probes: 8, max_rank: 25 },
            policy: SolverPolicy::default(),
            seed: 0,
        }
    }
}

/// Which space a stored warm-start seed lives in. A grid-space iterate
/// is meaningless as a data-space seed (and vice versa) even when the
/// lengths coincide (n == M is possible), so seeds are tagged and a
/// space switch silently drops the stale seed instead of feeding it to
/// the wrong solver.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SeedSpace {
    Data,
    Grid,
}

/// MVM-based GP regression model.
pub struct MvmGp {
    pub xs: Matrix,
    pub ys: Vec<f64>,
    /// D-SKI gradient observations (n × d, row i = ∇y at xs row i), set
    /// by [`Self::new_with_grads`]. When present, every training row
    /// contributes its value row *and* d gradient rows to the extended
    /// operator `W_ext (⊗K) W_extᵀ` (interleaved order — see
    /// [`crate::kernels::deriv_layout`]), and the train targets become
    /// the interleaved `(y, ∇y)` vector of length n·(1+d).
    grads: Option<Matrix>,
    pub hypers: GpHypers,
    pub cfg: MvmGpConfig,
    backend: Arc<dyn ContractionBackend>,
    /// Cached α = K̂⁻¹y for prediction.
    alpha: Option<Vec<f64>>,
    /// Grid-side stencil cache for O(1)-per-point means (rebuilt by
    /// `refresh`; None when the stored grid exceeds the cache budget).
    cache: Option<PredictCache>,
    /// The refresh-grade operator K̂ (Corollary 3.4's cached
    /// decomposition), kept so `predict_var` and snapshot building reuse
    /// it instead of re-running the Lanczos merge tree.
    refresh_op: Option<AffineOp>,
    /// The preconditioner built for `refresh_op` (set together with it),
    /// so repeated `predict_var` calls don't re-pay the rank-k column
    /// sampling against the same cached operator.
    refresh_pre: Option<Box<dyn Preconditioner>>,
    /// The hypers `refresh_op`/`refresh_pre` were built for — the cached
    /// pair is only served while `self.hypers` still matches (hypers are
    /// `pub` and the externally-set-hypers workflow mutates them).
    refresh_hypers: Option<GpHypers>,
    /// The most recent y-solve iterate (data-space α, or the grid-space
    /// q when solving in grid space — see [`SeedSpace`]), used to
    /// warm-start the next solve when `cfg.warm_start` is on.
    /// Interior-mutable so `&self` methods (`mll`) can read it and
    /// `mll_grad` can be called through `&self` from optimizers.
    warm: Mutex<Option<(SeedSpace, Vec<f64>)>>,
    /// Whether the cached α was recovered from a grid-space solve —
    /// recorded as provenance in serving snapshots.
    alpha_from_grid: bool,
}

impl MvmGp {
    pub fn new(xs: Matrix, ys: Vec<f64>, hypers: GpHypers, cfg: MvmGpConfig) -> Self {
        assert_eq!(xs.rows, ys.len());
        // Fold the policy's precision/preconditioner switches into the
        // CG config every solve site consumes. The policy only ever
        // *adds* — a caller that set `cfg.cg.precision`/`cfg.cg.precond`
        // directly keeps their choice under a default policy.
        let mut cfg = cfg;
        cfg.policy.fold_into(&mut cfg.cg);
        MvmGp {
            xs,
            ys,
            grads: None,
            hypers,
            cfg,
            backend: Arc::new(NativeBackend),
            alpha: None,
            cache: None,
            refresh_op: None,
            refresh_pre: None,
            refresh_hypers: None,
            warm: Mutex::new(None),
            alpha_from_grid: false,
        }
    }

    /// Build a D-SKI model with gradient observations: every training
    /// point carries its value `y_i` *and* its gradient `∇y_i` (row i of
    /// `grads`, n × d). Training and prediction run on the extended
    /// interpolation operator whose `W_ext (⊗K) W_extᵀ` approximates the
    /// full derivative kernel `[[K, ∂K], [∂K, ∂²K]]` (Eriksson et al.
    /// 2018). Gradient models require the KISS variant on a single-term
    /// dense grid (the differentiated stencils live on one tensor grid)
    /// and an RBF kernel — all three are typed errors here, not panics
    /// deep in operator construction.
    pub fn new_with_grads(
        xs: Matrix,
        ys: Vec<f64>,
        grads: Matrix,
        hypers: GpHypers,
        cfg: MvmGpConfig,
    ) -> Result<Self> {
        if grads.rows != xs.rows || grads.cols != xs.cols {
            return Err(Error::DimMismatch {
                context: "gradient observations (n × d, aligned with xs)",
                expected: xs.rows * xs.cols,
                got: grads.rows * grads.cols,
            });
        }
        if cfg.variant != MvmVariant::Kiss {
            return Err(Error::Config(
                "gradient observations require the kiss variant — the SKIP \
                 operator has no tensor-product W to differentiate"
                    .into(),
            ));
        }
        if matches!(cfg.grid, GridSpec::Sparse { .. }) {
            return Err(Error::Config(
                "gradient observations require a single-term dense grid — \
                 sparse (combination-technique) grids are unsupported"
                    .into(),
            ));
        }
        let mut gp = Self::new(xs, ys, hypers, cfg);
        gp.grads = Some(grads);
        Ok(gp)
    }

    /// The gradient observations, when this is a D-SKI model.
    pub fn grads(&self) -> Option<&Matrix> {
        self.grads.as_ref()
    }

    /// The train-target vector every y-solve consumes: plain `ys` for
    /// value-only models (borrowed — zero cost on the common path), the
    /// interleaved `[y_i, ∇y_i·e_0, …, ∇y_i·e_{d−1}]` vector of length
    /// n·(1+d) for gradient models, aligned row-for-row with the
    /// extended operator.
    pub fn train_targets(&self) -> Cow<'_, [f64]> {
        match &self.grads {
            None => Cow::Borrowed(&self.ys[..]),
            Some(g) => {
                let d = self.xs.cols;
                let mut t = Vec::with_capacity(self.ys.len() * (1 + d));
                for (i, &y) in self.ys.iter().enumerate() {
                    t.push(y);
                    t.extend_from_slice(g.row(i));
                }
                Cow::Owned(t)
            }
        }
    }

    /// The preconditioner `cfg.cg.precond` describes, built for `op`
    /// with the exact noise shift σ_n² of hypers `h` — one setup
    /// (k column MVMs for `rank:k`) amortized across every solve against
    /// this operator.
    fn preconditioner(&self, op: &AffineOp, h: &GpHypers) -> Box<dyn Preconditioner> {
        build_preconditioner(op, Some(h.sn2()), self.cfg.cg.precond)
    }

    /// The warm-start seed for a `len`-length solve in `space`, when
    /// enabled and a previous solution of matching space AND length
    /// exists. Both filters matter: after a [`SolveSpace`] flip (or a
    /// system resize) the stored seed is stale, and feeding it to the
    /// other space's solver would be wrong even at coincidentally equal
    /// lengths — a mismatch is silently a cold start, never a panic.
    fn warm_seed_for(&self, space: SeedSpace, len: usize) -> Option<Vec<f64>> {
        if !self.cfg.policy.warm_start {
            return None;
        }
        let w = self.warm.lock().unwrap();
        match w.as_ref() {
            Some((s, v)) if *s == space && v.len() == len => Some(v.clone()),
            _ => None,
        }
    }

    /// Record the latest solve iterate (tagged with its space) for the
    /// next warm start. No-op when warm starts are disabled.
    fn store_warm(&self, space: SeedSpace, v: Vec<f64>) {
        if self.cfg.policy.warm_start {
            *self.warm.lock().unwrap() = Some((space, v));
        }
    }

    /// Swap the Lemma-3.1 contraction backend (e.g. the PJRT artifact
    /// executor from `crate::runtime`).
    pub fn with_backend(mut self, backend: Arc<dyn ContractionBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Build the noise-shifted covariance operator K̂ for hypers `h`.
    ///
    /// Deterministic given `seed` — the heart of common-random-numbers
    /// finite differences.
    pub fn build_operator(&self, h: &GpHypers, seed: u64) -> Result<AffineOp> {
        self.build_operator_with_rank(h, seed, self.cfg.rank)
    }

    /// As [`build_operator`](Self::build_operator) with an explicit
    /// Lanczos rank (used by `refresh` for the high-accuracy solve).
    pub fn build_operator_with_rank(
        &self,
        h: &GpHypers,
        seed: u64,
        rank: usize,
    ) -> Result<AffineOp> {
        let d = self.xs.cols;
        // A mismatched rectilinear spec is a typed error up front, not an
        // index panic deep inside operator construction.
        self.cfg.grid.validate_for_dim(d)?;
        let inner: Box<dyn LinearOp> = match self.cfg.variant {
            MvmVariant::Skip => {
                let kern = ProductKernel::rbf(d, h.ell(), 1.0);
                let skis = (0..d)
                    .map(|k| {
                        SkiOp::new(
                            &self.xs.col(k),
                            &kern.factors[k],
                            self.cfg.grid.size_for_dim(k),
                        )
                    })
                    .collect::<Result<Vec<SkiOp>>>()?;
                let comps: Vec<SkipComponent> = skis
                    .iter()
                    .map(|s| SkipComponent::Op(s as &dyn LinearOp))
                    .collect();
                let mut rng = Rng::new(seed);
                Box::new(SkipOp::build(comps, rank, self.backend.clone(), &mut rng))
            }
            MvmVariant::Kiss => {
                // Dense tensor specs must fit the explicit cell cap (the
                // historical d ≲ 5 regime); sparse specs break it.
                if !matches!(self.cfg.grid, GridSpec::Sparse { .. }) {
                    match self.cfg.grid.total_points(d) {
                        Some(cells) if cells <= KRON_MAX_CELLS => {}
                        _ => {
                            return Err(Error::Grid(format!(
                                "dense Kronecker grid {} in d={d} exceeds \
                                 {KRON_MAX_CELLS} cells — use GridSpec::Sparse \
                                 to break the m^d barrier",
                                self.cfg.grid.describe()
                            )))
                        }
                    }
                }
                let kern = ProductKernel::rbf(d, h.ell(), 1.0);
                if self.grads.is_some() {
                    // D-SKI: the extended operator interleaves value and
                    // gradient stencil rows; the single-term dense grid is
                    // guaranteed by `new_with_grads`.
                    let axes = self.fitted_grid_axes()?;
                    Box::new(KroneckerSkiOp::with_grids_grad(&self.xs, &kern, axes))
                } else {
                    let grid = build_grid(&self.xs, &self.cfg.grid)?;
                    grid_ski_operator(&self.xs, &kern, grid.as_ref())
                }
            }
        };
        Ok(AffineOp { inner, scale: h.sf2(), shift: h.sn2() })
    }

    /// Build the KISS term decomposition once and hand the *same*
    /// `Arc`-shared [`KroneckerSkiOp`]s to both solve spaces: the
    /// data-space covariance view (for SLQ log-determinants, variance
    /// block-solves, preconditioner setup) and the grid-space
    /// normal-equations system. One stencil decode, two views,
    /// float-identical kernel arithmetic.
    fn build_grid_system(&self, h: &GpHypers) -> Result<(AffineOp, GridSystem)> {
        let d = self.xs.cols;
        self.cfg.grid.validate_for_dim(d)?;
        if !matches!(self.cfg.grid, GridSpec::Sparse { .. }) {
            match self.cfg.grid.total_points(d) {
                Some(cells) if cells <= KRON_MAX_CELLS => {}
                _ => {
                    return Err(Error::Grid(format!(
                        "dense Kronecker grid {} in d={d} exceeds \
                         {KRON_MAX_CELLS} cells — use GridSpec::Sparse \
                         to break the m^d barrier",
                        self.cfg.grid.describe()
                    )))
                }
            }
        }
        let kern = ProductKernel::rbf(d, h.ell(), 1.0);
        let parts: Vec<(f64, Arc<KroneckerSkiOp>)> = if self.grads.is_some() {
            // D-SKI: one extended single-term operator; the same Arc
            // serves the grid system and the data-space view below.
            let axes = self.fitted_grid_axes()?;
            vec![(
                1.0,
                Arc::new(KroneckerSkiOp::with_grids_grad(&self.xs, &kern, axes)),
            )]
        } else {
            let grid = build_grid(&self.xs, &self.cfg.grid)?;
            grid_ski_parts(&self.xs, &kern, grid.as_ref())
                .into_iter()
                .map(|(c, op)| (c, Arc::new(op)))
                .collect()
        };
        // Data-space view over Arc clones — `ArcOp` is pure delegation,
        // so this is the `grid_ski_operator` composition bit-for-bit.
        let inner: Box<dyn LinearOp> = if parts.len() == 1 && parts[0].0 == 1.0 {
            Box::new(ArcOp(parts[0].1.clone()))
        } else {
            let terms: Vec<Box<dyn LinearOp>> = parts
                .iter()
                .map(|(c, op)| {
                    Box::new(AffineOp {
                        inner: Box::new(ArcOp(op.clone())),
                        scale: *c,
                        shift: 0.0,
                    }) as Box<dyn LinearOp>
                })
                .collect();
            Box::new(SumOp { terms })
        };
        let op = AffineOp { inner, scale: h.sf2(), shift: h.sn2() };
        let sys = GridSystem::new(parts, h.sf2(), h.sn2())?;
        Ok((op, sys))
    }

    /// Resolve [`SolverPolicy::space`] for this model: the grid
    /// system plus the matching data-space covariance view when y-solves
    /// should run in grid space, `None` for the data-space path.
    ///
    /// `Auto` falls back to data space when grid space is infeasible
    /// (SKIP variant, over-budget `WᵀW` band, degenerate axes); explicit
    /// `Grid` turns those into typed errors instead.
    fn grid_solver(&self, h: &GpHypers) -> Result<Option<(AffineOp, GridSystem)>> {
        let explicit = match self.cfg.policy.space {
            SolveSpace::Data => return Ok(None),
            SolveSpace::Grid => true,
            SolveSpace::Auto => false,
        };
        if self.cfg.variant != MvmVariant::Kiss {
            return if explicit {
                Err(Error::Config(
                    "solve_space=grid requires the kiss variant — the SKIP \
                     operator has no tensor-product W to project through"
                        .into(),
                ))
            } else {
                Ok(None)
            };
        }
        match self.build_grid_system(h) {
            Ok(pair) => Ok(Some(pair)),
            Err(Error::Grid(_)) if !explicit => {
                // Auto: infeasible grids (over-budget band, degenerate
                // axes) quietly take the data-space path instead.
                crate::coordinator::metrics::global().incr("solver.space.fallback", 1);
                Ok(None)
            }
            Err(e) => Err(e),
        }
    }

    /// Stochastic estimate of the marginal log likelihood (Eq. 3).
    ///
    /// The y-solve is preconditioned per `cfg.cg.precond` and
    /// warm-started from the last `mll_grad`/`refresh` solution (the
    /// seed only moves the CG starting point — the estimate still
    /// converges to `cfg.cg.tol`). `mll` never *writes* the warm state,
    /// so repeated calls at the same (h, seed) stay deterministic.
    pub fn mll(&self, h: &GpHypers, seed: u64) -> Result<f64> {
        self.mll_impl(h, seed, None)
    }

    /// [`mll`](Self::mll) with an optional pre-built preconditioner.
    /// PCG is correct for *any* SPD `M`, so `mll_grad`'s finite-difference
    /// evaluations at ℓ·e^{±h} reuse the preconditioner built at ℓ
    /// instead of paying a fresh rank-k column sampling per FD point
    /// (ADAM's perturbations are small, so it stays a good `M`).
    fn mll_impl(
        &self,
        h: &GpHypers,
        seed: u64,
        pre: Option<&dyn Preconditioner>,
    ) -> Result<f64> {
        // Gradient models train on the interleaved (y, ∇y) targets of the
        // extended system; N = n·(1+d) there, plain n otherwise.
        let ys = self.train_targets();
        let n = ys.len() as f64;
        if let Some((op, sys)) = self.grid_solver(h)? {
            // Grid space: the y-solve runs on the m×m normal equations
            // (per-iteration cost independent of n); SLQ stays in data
            // space over the shared-Arc covariance view.
            let x0 = self.warm_seed_for(SeedSpace::Grid, sys.grid_dim());
            let sol = grid_cg_solve(&sys, &ys, x0.as_deref(), self.cfg.cg);
            let fit: f64 = ys.iter().zip(&sol.alpha).map(|(y, a)| y * a).sum();
            let mut rng = Rng::new(seed ^ LOGDET_STREAM);
            let logdet = slq_logdet(&op, self.cfg.slq, &mut rng);
            return Ok(
                -0.5 * fit - 0.5 * logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
            );
        }
        crate::coordinator::metrics::global().incr("solver.space.data", 1);
        let op = self.build_operator(h, seed)?;
        let built;
        let pre: &dyn Preconditioner = match pre {
            Some(p) => p,
            None => {
                built = self.preconditioner(&op, h);
                built.as_ref()
            }
        };
        let x0 = self.warm_seed_for(SeedSpace::Data, ys.len());
        let sol = cg_solve_with(&op, &ys, pre, x0.as_deref(), self.cfg.cg);
        let fit: f64 = ys.iter().zip(&sol.x).map(|(y, a)| y * a).sum();
        let mut rng = Rng::new(seed ^ LOGDET_STREAM);
        let logdet = slq_logdet(&op, self.cfg.slq, &mut rng);
        Ok(-0.5 * fit - 0.5 * logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// One training step's gradient: analytic in σ_f², σ_n²; CRN central
    /// FD in log ℓ. Returns (mll_estimate, grad).
    ///
    /// The predictive solve `K̂⁻¹y` and the Hutchinson trace probes
    /// `K̂⁻¹zᵢ` ride in **one block-CG call**: every CG iteration costs a
    /// single fused SKIP block MVM for all 1 + p right-hand sides instead
    /// of 1 + p independent operator traversals. The block solve is
    /// preconditioned per `cfg.cg.precond`, and with `cfg.warm_start` the
    /// y-column is seeded with the previous step's α (ADAM moves the
    /// hypers a little per step, so the old α is a near-solution and the
    /// y-column converges in a handful of iterations).
    pub fn mll_grad(&self, h: &GpHypers, seed: u64) -> Result<(f64, Vec<f64>)> {
        // The hyper-gradient algebra below survives the D-SKI extension
        // unchanged: the derivative kernel scales linearly in σ_f², so
        // K̂ = σ_f²·B + σ_n²·I still holds row-for-row over the extended
        // system and the quad/trace identities carry over with
        // N = targets.len().
        let ys = self.train_targets();
        let n = ys.len();
        // Hutchinson probes from the fixed stream (same draws as the
        // historical one-solve-per-probe loop, for seed compatibility).
        let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
        let num_tr_probes = self.cfg.slq.num_probes.clamp(2, 6);
        let probes: Vec<Vec<f64>> =
            (0..num_tr_probes).map(|_| rng.rademacher_vec(n)).collect();

        // Solve K̂⁻¹[y | z₁ … z_p] in whichever space is configured.
        // `pre_for_fd` carries the data-space preconditioner to the CRN
        // finite-difference evaluations below; grid solves are
        // unpreconditioned by design, so it stays `None` there.
        let (alpha, probe_sols, pre_for_fd): (
            Vec<f64>,
            Vec<Vec<f64>>,
            Option<Box<dyn Preconditioner>>,
        ) = if let Some((_op, sys)) = self.grid_solver(h)? {
            let x0 = self.warm_seed_for(SeedSpace::Grid, sys.grid_dim());
            let sol = grid_cg_solve(&sys, &ys, x0.as_deref(), self.cfg.cg);
            self.store_warm(SeedSpace::Grid, sol.v.clone());
            // Probe columns are fresh Rademacher draws every step — no
            // warm seed exists for them, so they solve cold one by one.
            let probe_sols = probes
                .iter()
                .map(|z| grid_cg_solve(&sys, z, None, self.cfg.cg).alpha)
                .collect();
            (sol.alpha, probe_sols, None)
        } else {
            crate::coordinator::metrics::global().incr("solver.space.data", 1);
            let op = self.build_operator(h, seed)?;
            let mut rhs = Matrix::zeros(n, 1 + num_tr_probes);
            rhs.set_col(0, &ys);
            for (j, z) in probes.iter().enumerate() {
                rhs.set_col(1 + j, z);
            }
            let pre = self.preconditioner(&op, h);
            // Seed only the y-column; the probe columns are fresh draws
            // every step and start cold (a zero column seeds r₀ = b).
            let x0 = self.warm_seed_for(SeedSpace::Data, n).map(|w| {
                let mut x0 = Matrix::zeros(n, 1 + num_tr_probes);
                x0.set_col(0, &w);
                x0
            });
            let sol =
                block_cg_solve_with(&op, &rhs, pre.as_ref(), x0.as_ref(), self.cfg.cg);
            let alpha = sol.x.col(0);
            self.store_warm(SeedSpace::Data, alpha.clone());
            let probe_sols = (0..num_tr_probes).map(|j| sol.x.col(1 + j)).collect();
            (alpha, probe_sols, Some(pre))
        };
        let ya: f64 = ys.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        let aa: f64 = alpha.iter().map(|a| a * a).sum();

        // tr(K̂⁻¹) via Hutchinson from the probe solves.
        let mut tr_kinv = 0.0;
        for (z, s) in probes.iter().zip(&probe_sols) {
            tr_kinv += z.iter().zip(s).map(|(a, b)| a * b).sum::<f64>();
        }
        tr_kinv /= num_tr_probes as f64;

        // αᵀKα = αᵀK̂α − σ_n²‖α‖² = yᵀα − σ_n²‖α‖².
        let quad_k = ya - h.sn2() * aa;
        // tr(K̂⁻¹K) = n − σ_n² tr(K̂⁻¹).
        let tr_kinv_k = n as f64 - h.sn2() * tr_kinv;
        let g_sf2 = 0.5 * quad_k - 0.5 * tr_kinv_k;
        let g_sn2 = h.sn2() * (0.5 * aa - 0.5 * tr_kinv);

        // log ℓ: CRN central finite difference of the full MLL.
        let fd_h = 1e-2;
        let mut hp = *h;
        hp.log_ell += fd_h;
        let mut hm = *h;
        hm.log_ell -= fd_h;
        let lp = self.mll_impl(&hp, seed, pre_for_fd.as_deref())?;
        let lm = self.mll_impl(&hm, seed, pre_for_fd.as_deref())?;
        let g_ell = (lp - lm) / (2.0 * fd_h);

        // MLL at θ (reuse fit term; logdet from the CRN midpoint average —
        // good enough for the training trace).
        let mll_mid = 0.5 * (lp + lm);
        Ok((mll_mid, vec![g_ell, g_sf2, g_sn2]))
    }

    /// Train with ADAM. Returns MLL trace. Refreshes the predictive cache.
    ///
    /// The lengthscale is floored at 2/3 of the median-distance heuristic:
    /// the rank-r SKIP operator truncates the kernel spectrum, which
    /// *underestimates* the log-determinant for short lengthscales (the
    /// kernel's effective rank grows as ℓ shrinks — paper §7's
    /// rank(A∘B) ≤ rank(A)·rank(B) caveat). Left unchecked, that bias
    /// rewards ever-shorter ℓ, walking the optimizer out of the regime
    /// where the approximation (and hence the MLL estimate) is valid.
    pub fn fit(&mut self, steps: usize, lr: f64) -> Result<Vec<f64>> {
        let mut adam = Adam::new(3, lr);
        let mut params = self.hypers.to_vec();
        let ell_floor = GpHypers::init_for_dim(self.xs.cols).log_ell + (2.0f64 / 3.0).ln();
        let sn2_floor = (1e-3f64).ln();
        let mut trace = Vec::with_capacity(steps);
        for step in 0..steps {
            let h = GpHypers::from_vec(&params);
            // Fresh randomness per step; common within the step.
            let seed = self.cfg.seed.wrapping_add(step as u64);
            let (mll, grad) = self.mll_grad(&h, seed)?;
            trace.push(mll);
            adam.step_ascend(&mut params, &grad);
            params[0] = params[0].max(ell_floor);
            params[2] = params[2].max(sn2_floor);
        }
        self.hypers = GpHypers::from_vec(&params);
        self.refresh()?;
        Ok(trace)
    }

    /// Recompute α for the current hyperparameters at `refresh_rank`
    /// accuracy (see the config docs: the solve amplifies operator error,
    /// so prediction uses a higher-rank operator than training).
    pub fn refresh(&mut self) -> Result<()> {
        let cg = CgConfig { max_iters: self.cfg.cg.max_iters.max(200), ..self.cfg.cg };
        let ys = self.train_targets().into_owned();
        if let Some((op, sys)) = self.grid_solver(&self.hypers)? {
            // Grid space: α is recovered from the grid solve; the
            // data-space covariance view (shared Arcs, so float-identical
            // to the grid system's kernel arithmetic) is still cached for
            // `predict_var`'s block solves and its preconditioner.
            let x0 = if self.cfg.policy.warm_start {
                self.warm_seed_for(SeedSpace::Grid, sys.grid_dim())
                    .or_else(|| self.alpha.as_ref().map(|a| sys.seed_from_alpha(a)))
            } else {
                None
            };
            let sol = grid_cg_solve(&sys, &ys, x0.as_deref(), cg);
            self.store_warm(SeedSpace::Grid, sol.v.clone());
            self.alpha = Some(sol.alpha);
            self.alpha_from_grid = true;
            self.cache = self.build_stencil_cache();
            let pre = self.preconditioner(&op, &self.hypers);
            self.refresh_op = Some(op);
            self.refresh_pre = Some(pre);
            self.refresh_hypers = Some(self.hypers);
            return Ok(());
        }
        crate::coordinator::metrics::global().incr("solver.space.data", 1);
        let op = self.build_operator_with_rank(
            &self.hypers,
            self.cfg.seed,
            self.refresh_grade_rank(),
        )?;
        let pre = self.preconditioner(&op, &self.hypers);
        // Seed with the best solution on hand: the previous refresh's α,
        // else the last training step's (the refresh-grade operator is a
        // higher-rank build of the same K̂, so either is a near-solution).
        // α is a valid data-space seed whichever space produced it.
        let x0 = if self.cfg.policy.warm_start {
            self.alpha
                .clone()
                .or_else(|| self.warm_seed_for(SeedSpace::Data, ys.len()))
        } else {
            None
        };
        let sol = cg_solve_with(&op, &ys, pre.as_ref(), x0.as_deref(), cg);
        self.store_warm(SeedSpace::Data, sol.x.clone());
        self.alpha = Some(sol.x);
        self.alpha_from_grid = false;
        self.cache = self.build_stencil_cache();
        self.refresh_op = Some(op);
        self.refresh_pre = Some(pre);
        self.refresh_hypers = Some(self.hypers);
        Ok(())
    }

    /// The refresh-grade operator built by the last `refresh`.
    /// `predict_var` and `serve::snapshot` reuse this cached
    /// decomposition instead of rebuilding the merge tree. Returns `None`
    /// before the first `refresh` — and after the (pub) hypers have been
    /// mutated since it, so a stale operator is never served.
    pub fn refresh_operator(&self) -> Option<&AffineOp> {
        if self.refresh_hypers != Some(self.hypers) {
            return None;
        }
        self.refresh_op.as_ref()
    }

    /// Lanczos rank for prediction-grade solves. The rank needed for a
    /// faithful solve grows with d (the Hadamard product's effective rank
    /// compounds per factor — §7); 14·d matches the empirical requirement
    /// on the d = 9…32 suite. One formula shared by `refresh`,
    /// `predict_var`, and `serve::snapshot` so they can never diverge.
    pub fn refresh_grade_rank(&self) -> usize {
        self.cfg
            .refresh_rank
            .max(self.cfg.rank)
            .max(14 * self.xs.cols)
    }

    /// Cached α = K̂⁻¹y (None before `fit`/`refresh`); read by the serving
    /// layer when freezing the model into a snapshot.
    pub fn alpha(&self) -> Option<&[f64]> {
        self.alpha.as_deref()
    }

    /// Whether the cached α came out of a grid-space solve (back-projected
    /// `(y − Wq)/σ_n²`) rather than data-space CG. Pure provenance — the
    /// two αs agree to solver tolerance — recorded in snapshots so a
    /// serving fleet can audit which engine produced each artifact.
    pub fn alpha_solved_in_grid_space(&self) -> bool {
        self.alpha_from_grid
    }

    /// The fitted axes of this model's inducing grid, when the spec is a
    /// single-term dense (rectilinear/uniform) grid — what the streaming
    /// layer (`crate::stream::IncrementalState::from_mvm`) freezes for
    /// online updates. Sparse (multi-term) specs are a typed error.
    pub fn fitted_grid_axes(&self) -> Result<Vec<Grid1d>> {
        let grid = build_grid(&self.xs, &self.cfg.grid)?;
        let terms = grid.terms();
        if terms.len() != 1 || terms[0].coeff != 1.0 {
            return Err(Error::Grid(format!(
                "{} is not a single-term dense grid ({} terms)",
                self.cfg.grid.describe(),
                terms.len()
            )));
        }
        Ok(terms[0].axes.clone())
    }

    /// The grid-side stencil cache backing `predict_mean`, when the grid
    /// fits the budget (None for high-d dense specs, which predict
    /// densely).
    pub fn predict_cache(&self) -> Option<&PredictCache> {
        self.cache.as_ref()
    }

    /// Build the mean-only stencil cache on the training grid, or None
    /// when the stored cells exceed [`PREDICT_CACHE_MAX_CELLS`] (or the
    /// grid cannot be fit — prediction then uses the dense path).
    fn build_stencil_cache(&self) -> Option<PredictCache> {
        let alpha = self.alpha.as_ref()?;
        let cells = self.cfg.grid.total_points(self.xs.cols)?;
        if cells > PREDICT_CACHE_MAX_CELLS {
            return None;
        }
        if self.grads.is_some() {
            // D-SKI: the mean cache is u = σ_f²(⊗K)(W_extᵀα) — identical
            // query-side algebra, gradient rows scattered through
            // differentiated stencils (`serve::cache::build_grad_cache`).
            let axes = self.fitted_grid_axes().ok()?;
            let has_grad = vec![true; self.xs.rows];
            return build_grad_cache(
                &self.xs,
                &has_grad,
                alpha,
                &self.hypers,
                self.cfg.grid.clone(),
                axes,
                None,
            )
            .ok();
        }
        let grid = build_grid(&self.xs, &self.cfg.grid).ok()?;
        PredictCache::build(&self.xs, alpha, &self.hypers, grid.as_ref(), None).ok()
    }

    /// Predictive mean (Eq. 1): `μ* = K_{*X} α`, served from the grid-side
    /// stencil cache shared with `serve::cache` — one sparse stencil dot
    /// per point (per grid term) instead of the O(n·d) dense cross-kernel
    /// row. Falls back to
    /// [`predict_mean_dense`](Self::predict_mean_dense) when the grid
    /// exceeds the cache budget; debug builds cross-check the stencil
    /// path against the dense reference.
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        assert!(self.alpha.is_some(), "call fit/refresh first");
        match &self.cache {
            Some(cache) => {
                let out = cache.predict_mean(xtest);
                #[cfg(debug_assertions)]
                self.debug_check_stencil_mean(&out, xtest);
                out
            }
            None => self.predict_mean_dense(xtest),
        }
    }

    /// Reference predictive mean via the exact dense cross-covariance,
    /// O(n*·n·d) — the path `predict_mean` used historically; kept as the
    /// fallback for budget-exceeding grids and as the debug-assert oracle
    /// for the stencil path.
    pub fn predict_mean_dense(&self, xtest: &Matrix) -> Vec<f64> {
        let alpha = self.alpha.as_ref().expect("call fit/refresh first");
        let kern = ProductKernel::rbf(self.xs.cols, self.hypers.ell(), self.hypers.sf2());
        if self.grads.is_some() {
            // Gradient rows contribute through the derivative
            // cross-covariances: μ(x*) = Σ_r α_r · k_r(x*) with k_r the
            // value or ∂-row of the exact derivative kernel.
            let layout =
                deriv_layout(&vec![true; self.xs.rows], self.xs.cols);
            return (0..xtest.rows)
                .map(|j| {
                    let xj = xtest.row(j);
                    layout
                        .iter()
                        .zip(alpha)
                        .map(|(&(pi, da), &a)| {
                            a * kern.eval_deriv(self.xs.row(pi), xj, da, None)
                        })
                        .sum()
                })
                .collect();
        }
        let mut out = Vec::with_capacity(xtest.rows);
        for i in 0..xtest.rows {
            let xi = xtest.row(i);
            let mut acc = 0.0;
            for j in 0..self.xs.rows {
                acc += kern.eval(xi, self.xs.row(j)) * alpha[j];
            }
            out.push(acc);
        }
        out
    }

    /// Gradient of the predictive mean (n* × d): served from the
    /// grid-side cache through differentiated query stencils
    /// ([`PredictCache::predict_grad`]), falling back to the exact
    /// derivative cross-covariances when the grid exceeds the cache
    /// budget. Available on value-only models too — the posterior mean
    /// of a smooth kernel is differentiable whether or not gradients
    /// were observed.
    pub fn predict_grad(&self, xtest: &Matrix) -> Matrix {
        assert!(self.alpha.is_some(), "call fit/refresh first");
        match &self.cache {
            Some(cache) => cache.predict_grad(xtest),
            None => self.predict_grad_dense(xtest),
        }
    }

    /// Reference predictive-mean gradient via the exact derivative
    /// cross-covariances, O(n*·N·d²) — the oracle for the differentiated
    /// stencil path.
    pub fn predict_grad_dense(&self, xtest: &Matrix) -> Matrix {
        let alpha = self.alpha.as_ref().expect("call fit/refresh first");
        let d = self.xs.cols;
        let kern = ProductKernel::rbf(d, self.hypers.ell(), self.hypers.sf2());
        let layout =
            deriv_layout(&vec![self.grads.is_some(); self.xs.rows], d);
        Matrix::from_fn(xtest.rows, d, |j, a| {
            let xj = xtest.row(j);
            layout
                .iter()
                .zip(alpha)
                .map(|(&(pi, da), &al)| {
                    al * kern.eval_deriv(self.xs.row(pi), xj, da, Some(a))
                })
                .sum()
        })
    }

    #[cfg(debug_assertions)]
    fn debug_check_stencil_mean(&self, got: &[f64], xtest: &Matrix) {
        // Only cross-check problems small enough that the dense oracle is
        // cheap; the stencil path differs from dense by the SKI
        // interpolation error, amplified by ‖α‖₁. Multi-term (sparse)
        // caches carry the combination-technique error on top and are
        // covered by their own integration tests instead.
        if xtest.rows * self.xs.rows > 250_000 {
            return;
        }
        // Gradient models: the extended α's ‖·‖₁ bound would need the
        // differentiated-stencil error constants on top; the D-SKI
        // property tests hold that path to an explicit oracle instead.
        if self.grads.is_some() {
            return;
        }
        let cache = self.cache.as_ref().expect("stencil check without cache");
        if cache.terms().len() != 1 {
            return;
        }
        let axes = &cache.terms()[0].axes;
        // Extrapolated points (outside the grid span) get clamped,
        // legitimately degraded stencils — only interior points are held
        // to the interpolation-accuracy bound.
        let interior = |row: &[f64]| {
            row.iter()
                .zip(axes)
                .all(|(&x, g)| x >= g.min && x <= g.max())
        };
        let want = self.predict_mean_dense(xtest);
        let mut err = 0.0f64;
        let mut count = 0usize;
        let mut scale = self.hypers.sf2().max(1.0);
        for i in 0..xtest.rows {
            if !interior(xtest.row(i)) {
                continue;
            }
            err += (got[i] - want[i]).abs();
            scale = scale.max(want[i].abs());
            count += 1;
        }
        if count == 0 {
            return;
        }
        err /= count as f64;
        // The stencil error is bounded by (per-entry kernel interpolation
        // error)·‖α‖₁, so the tolerance carries an ‖α‖₁ term — a fixed
        // fraction of scale alone would misfire on small-noise models
        // whose α is legitimately large.
        let alpha_l1: f64 = self
            .alpha
            .as_ref()
            .map(|a| a.iter().map(|v| v.abs()).sum())
            .unwrap_or(0.0);
        let tol = 0.05 * scale + 1e-3 * alpha_l1;
        debug_assert!(
            err <= tol,
            "stencil predict_mean drifted from the dense reference: \
             mae {err}, tol {tol} (scale {scale}, ‖α‖₁ {alpha_l1})"
        );
    }

    /// Latent predictive variance (Eq. 2): `k** − k*ᵀ K̂⁻¹ k*`, with all
    /// n* cross-covariance solves riding **one block-CG call** against the
    /// refresh-grade operator (the batched multi-RHS engine's test-time
    /// analogue of the training-path gradient solve).
    ///
    /// Like `ExactGp::predict_var`, this is the noise-free latent
    /// variance; add `hypers.sn2()` for observation variance.
    pub fn predict_var(&self, xtest: &Matrix) -> Result<Vec<f64>> {
        assert!(self.alpha.is_some(), "call fit/refresh first");
        let d = self.xs.cols;
        let kern = ProductKernel::rbf(d, self.hypers.ell(), self.hypers.sf2());
        // Gradient models solve against the extended system, so the
        // cross-covariance block carries the derivative rows too (N × n*).
        let kx = if self.grads.is_some() {
            kern.gram_deriv(
                &self.xs,
                &vec![true; self.xs.rows],
                xtest,
                &vec![false; xtest.rows],
            )
        } else {
            kern.gram(&self.xs, xtest) // n × n*
        };
        // Reuse the cached refresh-grade operator when it is current for
        // these hypers (`refresh_operator` returns None when stale);
        // rebuild otherwise.
        let built;
        let cached = self.refresh_operator();
        let op: &AffineOp = match cached {
            Some(op) => op,
            None => {
                built = self.build_operator_with_rank(
                    &self.hypers,
                    self.cfg.seed,
                    self.refresh_grade_rank(),
                )?;
                &built
            }
        };
        let cg = CgConfig { max_iters: self.cfg.cg.max_iters.max(200), ..self.cfg.cg };
        // Reuse the preconditioner cached with the refresh operator; only
        // a freshly built operator needs a fresh (rank-k column-sampling)
        // setup.
        let built_pre;
        let pre: &dyn Preconditioner = match (cached.is_some(), &self.refresh_pre) {
            (true, Some(p)) => p.as_ref(),
            _ => {
                built_pre = self.preconditioner(op, &self.hypers);
                built_pre.as_ref()
            }
        };
        let sol = block_cg_solve_with(op, &kx, pre, None, cg);
        Ok((0..xtest.rows)
            .map(|j| {
                let quad = dot(&kx.col(j), &sol.x.col(j));
                (self.hypers.sf2() - quad).max(1e-12)
            })
            .collect())
    }
}

/// Stream-split constant: keeps the SLQ probe stream decoupled from the
/// operator-build (Lanczos probe) stream while staying seed-deterministic.
const LOGDET_STREAM: u64 = 0xD1B5_4A32_D192_ED03;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mae, Rng};

    fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let f = |row: &[f64]| -> f64 {
            row.iter().enumerate().map(|(k, &x)| ((k + 1) as f64 * x).sin()).sum()
        };
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
        let xt = Matrix::from_fn(50, d, |_, _| rng.uniform_in(-0.9, 0.9));
        let yt: Vec<f64> = (0..50).map(|i| f(xt.row(i))).collect();
        (xs, ys, xt, yt)
    }

    #[test]
    fn skip_gp_regresses_2d() {
        let (xs, ys, xt, yt) = toy(200, 2, 1);
        let cfg =
            MvmGpConfig { grid: GridSpec::uniform(64), rank: 30, ..Default::default() };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.5, 1.0, 0.05), cfg);
        gp.refresh().unwrap();
        let pred = gp.predict_mean(&xt);
        let err = mae(&pred, &yt);
        assert!(err < 0.15, "mae {err}");
    }

    #[test]
    fn kiss_gp_regresses_2d() {
        let (xs, ys, xt, yt) = toy(200, 2, 2);
        let cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(32),
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.5, 1.0, 0.05), cfg);
        gp.refresh().unwrap();
        let pred = gp.predict_mean(&xt);
        let err = mae(&pred, &yt);
        assert!(err < 0.15, "mae {err}");
    }

    #[test]
    fn sparse_kiss_gp_regresses_2d() {
        let (xs, ys, xt, yt) = toy(200, 2, 2);
        let cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::sparse(5),
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.5, 1.0, 0.05), cfg);
        gp.refresh().unwrap();
        let pred = gp.predict_mean(&xt);
        let err = mae(&pred, &yt);
        assert!(err < 0.15, "sparse-grid mae {err}");
        // The multi-term cache is live (not the dense fallback).
        assert!(gp.predict_cache().unwrap().terms().len() > 1);
    }

    #[test]
    fn skip_and_kiss_agree_on_small_problem() {
        let (xs, ys, xt, _) = toy(150, 2, 3);
        let h = GpHypers::new(0.7, 1.0, 0.1);
        let cfg_s =
            MvmGpConfig { grid: GridSpec::uniform(64), rank: 40, ..Default::default() };
        let cfg_k = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(64),
            ..Default::default()
        };
        let mut a = MvmGp::new(xs.clone(), ys.clone(), h, cfg_s);
        let mut b = MvmGp::new(xs, ys, h, cfg_k);
        a.refresh().unwrap();
        b.refresh().unwrap();
        let pa = a.predict_mean(&xt);
        let pb = b.predict_mean(&xt);
        assert!(mae(&pa, &pb) < 0.05, "mae between variants {}", mae(&pa, &pb));
    }

    #[test]
    fn dense_kron_high_d_is_a_typed_error() {
        let (xs, ys, _, _) = toy(40, 8, 12);
        let cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(100),
            ..Default::default()
        };
        let gp = MvmGp::new(xs, ys, GpHypers::init_for_dim(8), cfg);
        let err = match gp.build_operator(&gp.hypers, 0) {
            Ok(_) => panic!("dense 100^8 grid must refuse"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("Sparse"), "{err}");
    }

    #[test]
    fn mll_estimate_close_to_exact() {
        use crate::gp::exact::ExactGp;
        let (xs, ys, _, _) = toy(120, 2, 4);
        let h = GpHypers::new(0.8, 1.0, 0.1);
        let exact = ExactGp::new(xs.clone(), ys.clone(), h).mll(&h).unwrap();
        let cfg = MvmGpConfig {
            grid: GridSpec::uniform(64),
            rank: 40,
            slq: SlqConfig { num_probes: 30, max_rank: 40 },
            ..Default::default()
        };
        let gp = MvmGp::new(xs, ys, h, cfg);
        let est = gp.mll(&h, 11).unwrap();
        // The SKIP operator is a rank-truncated approximation of K and the
        // logdet is an SLQ estimate, so compare in nats *per datapoint*
        // (the exact MLL sits near zero here, making relative error
        // meaningless).
        let per_n = (est - exact).abs() / 120.0;
        assert!(per_n < 0.05, "mvm mll {est} vs exact {exact} ({per_n} nats/point)");
    }

    #[test]
    fn fit_improves_mll() {
        let (xs, ys, _, _) = toy(150, 2, 5);
        let cfg =
            MvmGpConfig { grid: GridSpec::uniform(48), rank: 25, ..Default::default() };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(2.5, 0.5, 0.5), cfg);
        let trace = gp.fit(15, 0.1).unwrap();
        assert!(
            trace.last().unwrap() > trace.first().unwrap(),
            "trace {:?}",
            trace
        );
    }

    #[test]
    fn stencil_cache_built_when_grid_fits() {
        let (xs, ys, xt, _) = toy(150, 2, 7);
        let cfg =
            MvmGpConfig { grid: GridSpec::uniform(48), rank: 30, ..Default::default() };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.7, 1.0, 0.05), cfg);
        gp.refresh().unwrap();
        let cache = gp.predict_cache().expect("2-D grid fits the budget");
        assert_eq!(cache.total_grid(), 48 * 48);
        // The stencil path tracks the dense reference closely.
        let fast = gp.predict_mean(&xt);
        let dense = gp.predict_mean_dense(&xt);
        assert!(mae(&fast, &dense) < 5e-3, "mae {}", mae(&fast, &dense));
    }

    #[test]
    fn high_dim_grid_falls_back_to_dense_path() {
        let (xs, ys, xt, _) = toy(60, 8, 8);
        let cfg = MvmGpConfig {
            grid: GridSpec::uniform(100),
            rank: 10,
            refresh_rank: 20,
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, GpHypers::init_for_dim(8), cfg);
        gp.refresh().unwrap();
        // 100⁸ cells blows any budget — no cache, but prediction works.
        assert!(gp.predict_cache().is_none());
        let pred = gp.predict_mean(&xt);
        assert_eq!(pred.len(), xt.rows);
        assert!(pred.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn predict_var_matches_exact_gp() {
        use crate::gp::exact::ExactGp;
        let (xs, ys, xt_all, _) = toy(150, 2, 9);
        // A 20-point query block keeps the debug-build block-CG quick.
        let xt = Matrix::from_fn(20, 2, |i, j| xt_all.get(i, j));
        let h = GpHypers::new(0.7, 1.0, 0.1);
        let mut exact = ExactGp::new(xs.clone(), ys.clone(), h);
        exact.refresh().unwrap();
        let want = exact.predict_var(&xt);
        let cfg = MvmGpConfig {
            grid: GridSpec::uniform(64),
            rank: 40,
            refresh_rank: 40,
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, h, cfg);
        gp.refresh().unwrap();
        let got = gp.predict_var(&xt).unwrap();
        assert!(mae(&got, &want) < 0.05, "var mae {}", mae(&got, &want));
        for v in &got {
            assert!(*v > 0.0 && *v <= h.sf2() + 1e-9);
        }
    }

    #[test]
    fn predict_var_small_at_data_large_far_away() {
        let (xs, ys, _, _) = toy(120, 2, 10);
        let x0 = [xs.get(0, 0), xs.get(0, 1)];
        let cfg = MvmGpConfig { grid: GridSpec::uniform(48), ..Default::default() };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.6, 1.0, 0.01), cfg);
        gp.refresh().unwrap();
        let xt = Matrix::from_vec(2, 2, vec![x0[0], x0[1], 50.0, -50.0]);
        let var = gp.predict_var(&xt).unwrap();
        assert!(var[0] < 0.1, "at-data var {}", var[0]);
        assert!(var[1] > 0.9, "far-field var {}", var[1]);
    }

    #[test]
    fn preconditioned_refresh_matches_plain() {
        use crate::solvers::PrecondSpec;
        let (xs, ys, xt, _) = toy(150, 2, 13);
        let h = GpHypers::new(0.7, 1.0, 0.05);
        let mut cfg_plain = MvmGpConfig {
            grid: GridSpec::uniform(48),
            rank: 30,
            policy: SolverPolicy { warm_start: false, ..Default::default() },
            ..Default::default()
        };
        cfg_plain.cg.tol = 1e-8;
        cfg_plain.cg.max_iters = 500;
        let mut cfg_pre = cfg_plain.clone();
        cfg_pre.cg.precond = PrecondSpec::PivChol { rank: 30 };
        let mut a = MvmGp::new(xs.clone(), ys.clone(), h, cfg_plain);
        let mut b = MvmGp::new(xs, ys, h, cfg_pre);
        a.refresh().unwrap();
        b.refresh().unwrap();
        let pa = a.predict_mean(&xt);
        let pb = b.predict_mean(&xt);
        assert!(mae(&pa, &pb) < 1e-4, "precond changed predictions: {}", mae(&pa, &pb));
    }

    #[test]
    fn second_refresh_warm_starts_from_alpha() {
        use crate::util::rel_err;
        // The refresh-grade operator build is seed-deterministic, so the
        // second refresh's warm seed is (numerically) the solution: it
        // converges at or within a step of the seed and must not move α.
        // (The exact zero-iteration bitwise guarantee is pinned at the
        // solver level in `cg::tests::warm_start_with_solution_is_bitwise_noop`.)
        let (xs, ys, _, _) = toy(120, 2, 14);
        let cfg =
            MvmGpConfig { grid: GridSpec::uniform(48), rank: 25, ..Default::default() };
        let mut gp = MvmGp::new(xs, ys, GpHypers::new(0.7, 1.0, 0.05), cfg);
        gp.refresh().unwrap();
        let a1 = gp.alpha().unwrap().to_vec();
        gp.refresh().unwrap();
        let drift = rel_err(gp.alpha().unwrap(), &a1);
        assert!(drift < 1e-4, "warm-started refresh moved α by {drift}");
    }

    #[test]
    fn crn_mll_is_deterministic() {
        let (xs, ys, _, _) = toy(80, 2, 6);
        let h = GpHypers::default_init();
        let gp = MvmGp::new(
            xs,
            ys,
            h,
            MvmGpConfig { grid: GridSpec::uniform(32), ..Default::default() },
        );
        let a = gp.mll(&h, 99).unwrap();
        let b = gp.mll(&h, 99).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn grid_and_data_space_refresh_agree() {
        // The tentpole invariant at unit-test scale (the cross-size sweep
        // lives in tests/gridspace_props.rs): both solve spaces target the
        // same certificate ‖K̂α − y‖ ≤ tol·‖y‖, so the recovered α and the
        // predictions must agree to solver tolerance.
        let (xs, ys, xt, _) = toy(200, 2, 20);
        let h = GpHypers::new(0.6, 1.0, 0.1);
        let mut cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(32),
            policy: SolverPolicy {
                space: SolveSpace::Data,
                warm_start: false,
                ..Default::default()
            },
            ..Default::default()
        };
        cfg.cg.tol = 1e-7;
        cfg.cg.max_iters = 600;
        let mut data = MvmGp::new(xs.clone(), ys.clone(), h, cfg.clone());
        cfg.policy.space = SolveSpace::Grid;
        let mut grid = MvmGp::new(xs, ys, h, cfg);
        data.refresh().unwrap();
        grid.refresh().unwrap();
        let da = data.alpha().unwrap();
        let ga = grid.alpha().unwrap();
        let am = mae(ga, da);
        assert!(am < 1e-4, "α disagreement between spaces: {am}");
        let pm = mae(&grid.predict_mean(&xt), &data.predict_mean(&xt));
        assert!(pm < 1e-4, "prediction disagreement between spaces: {pm}");
    }

    #[test]
    fn solve_space_flip_drops_stale_seed() {
        // A grid-space iterate (length M = 1024 here) is meaningless to
        // the data-space solver (length n = 200) and vice versa. Flipping
        // `solve_space` mid-training must silently cold-start, not panic
        // or feed the stale seed across spaces.
        let (xs, ys, _, _) = toy(200, 2, 21);
        let h = GpHypers::new(0.6, 1.0, 0.1);
        let cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(32),
            policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, h, cfg);
        // Writes a Grid-tagged warm seed.
        let (mll_g, grad_g) = gp.mll_grad(&h, 7).unwrap();
        gp.cfg.policy.space = SolveSpace::Data;
        let (mll_d, grad_d) = gp.mll_grad(&h, 7).unwrap();
        assert!(mll_g.is_finite() && mll_d.is_finite());
        assert!(grad_g.iter().chain(&grad_d).all(|g| g.is_finite()));
        // Same certificate in both spaces: the per-point MLL estimates
        // agree up to solver + probe noise.
        assert!(
            (mll_g - mll_d).abs() / 200.0 < 0.05,
            "grid-space mll {mll_g} vs data-space {mll_d}"
        );
        // Flip back: the Data-tagged seed is dropped just the same, and a
        // full grid-space refresh comes out finite.
        gp.cfg.policy.space = SolveSpace::Grid;
        gp.refresh().unwrap();
        assert!(gp.alpha().unwrap().iter().all(|a| a.is_finite()));
    }

    #[test]
    fn grid_space_requires_kiss_variant() {
        let (xs, ys, _, _) = toy(80, 2, 22);
        let cfg = MvmGpConfig {
            grid: GridSpec::uniform(32),
            policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
            ..Default::default()
        };
        let mut gp = MvmGp::new(xs, ys, GpHypers::default_init(), cfg);
        match gp.refresh() {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("kiss"), "unexpected message: {msg}")
            }
            other => {
                panic!("SKIP + solve_space=grid must be a config error, got {other:?}")
            }
        }
    }

    #[test]
    fn explicit_grid_rejects_over_budget_band_and_auto_falls_back() {
        // 13⁴ = 28 561 grid cells pass the dense-Kronecker cap, but the
        // WᵀW band (m·7⁴ ≈ 6.9e7 entries) just exceeds its ~0.5 GB
        // budget: explicit grid space is a typed refusal, while Auto
        // quietly solves the same model in data space.
        let (xs, ys, _, _) = toy(60, 4, 23);
        let cfg = MvmGpConfig {
            variant: MvmVariant::Kiss,
            grid: GridSpec::uniform(13),
            policy: SolverPolicy { space: SolveSpace::Grid, ..Default::default() },
            rank: 10,
            refresh_rank: 20,
            ..Default::default()
        };
        let h = GpHypers::init_for_dim(4);
        let mut gp = MvmGp::new(xs.clone(), ys.clone(), h, cfg.clone());
        match gp.refresh() {
            Err(Error::Grid(msg)) => {
                assert!(msg.contains("budget"), "unexpected message: {msg}")
            }
            other => panic!("over-budget band must be a grid error, got {other:?}"),
        }
        let mut cfg = cfg;
        cfg.policy.space = SolveSpace::Auto;
        let mut gp = MvmGp::new(xs, ys, h, cfg);
        gp.refresh().unwrap();
        assert!(gp.alpha().unwrap().iter().all(|a| a.is_finite()));
    }
}
