//! Multi-task Gaussian processes (paper §6; Bonilla et al. 2008).
//!
//! Covariance between observation (x, task i) and (x′, task j):
//! `k_input(x, x′) · k_task(i, j)` with `k_task = B Bᵀ + D` low-rank.
//! The full covariance factors as the Hadamard product
//! `K_multi = K_data ∘ (V M Vᵀ)` (Eq. 16 region), so SKIP applies: SKI the
//! 1-D data kernel, supply the task factor exactly — O(n + m log m + sq)
//! per MVM.
//!
//! Two inference paths:
//! - `mll_skip`: the paper's fast path (CG + SLQ over the SKIP operator).
//! - dense path (`mll_dense`, `fit_dense`): exact Cholesky algebra with
//!   analytic gradients for B, D, ℓ, σ_n² — used to *train* the task
//!   kernel on the modest-n childhood-growth workloads, and as the
//!   baseline the §6 "20× speedup" claim is measured against.

use super::adam::Adam;
use crate::kernels::{Stationary1d, TaskKernel};
use crate::linalg::{Cholesky, Matrix};
use crate::operators::{AffineOp, SkiOp, SkipComponent, SkipOp, TaskOp};
use crate::solvers::{cg_solve, slq_logdet, CgConfig, SlqConfig};
use crate::util::Rng;
use crate::Result;

/// Multi-task dataset: 1-D inputs, one task label per observation.
#[derive(Clone, Debug)]
pub struct MtgpData {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub task_of: Vec<usize>,
    pub num_tasks: usize,
}

impl MtgpData {
    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }
}

/// Configuration for the SKIP inference path.
#[derive(Clone, Debug)]
pub struct MtgpConfig {
    pub grid_m: usize,
    pub rank: usize,
    pub cg: CgConfig,
    pub slq: SlqConfig,
    pub seed: u64,
}

impl Default for MtgpConfig {
    fn default() -> Self {
        MtgpConfig {
            grid_m: 100,
            rank: 15,
            cg: CgConfig { max_iters: 60, tol: 1e-4, ..CgConfig::default() },
            slq: SlqConfig { num_probes: 6, max_rank: 20 },
            seed: 0,
        }
    }
}

/// Multi-task GP model.
pub struct Mtgp {
    pub data: MtgpData,
    pub input_kernel: Stationary1d,
    pub task_kernel: TaskKernel,
    pub sn2: f64,
    pub cfg: MtgpConfig,
    /// Cached α for prediction (dense path).
    alpha: Option<Vec<f64>>,
}

impl Mtgp {
    pub fn new(
        data: MtgpData,
        input_kernel: Stationary1d,
        task_rank: usize,
        sn2: f64,
        cfg: MtgpConfig,
    ) -> Self {
        let s = data.num_tasks;
        // B init: small random entries; D init: 0.1.
        let mut rng = Rng::new(cfg.seed.wrapping_add(17));
        let b = Matrix::from_fn(s, task_rank, |_, _| 0.3 * rng.normal());
        let task_kernel = TaskKernel::new(b, vec![0.1; s]);
        Mtgp { data, input_kernel, task_kernel, sn2, cfg, alpha: None }
    }

    /// Dense multi-task covariance K̂ (tests / training / dense baseline).
    pub fn khat_dense(&self) -> Matrix {
        let n = self.data.len();
        let mut k = Matrix::from_fn(n, n, |i, j| {
            self.input_kernel.eval(self.data.x[i], self.data.x[j])
                * self.task_kernel.eval(self.data.task_of[i], self.data.task_of[j])
        });
        k.add_diag(self.sn2);
        k
    }

    /// Exact MLL via Cholesky — O(n³).
    pub fn mll_dense(&self) -> Result<f64> {
        let n = self.data.len() as f64;
        let chol = Cholesky::new_with_jitter(&self.khat_dense(), 1e-10)?;
        let alpha = chol.solve(&self.data.y);
        let fit: f64 = self.data.y.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        Ok(-0.5 * fit - 0.5 * chol.logdet()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Build the SKIP operator for the current parameters:
    /// `K_data(SKI) ∘ (V M Vᵀ)(exact factor) + σ_n² I`.
    pub fn build_skip_operator(&self, seed: u64) -> AffineOp {
        let ski = SkiOp::new(&self.data.x, &self.input_kernel, self.cfg.grid_m)
            .expect("MTGP input-grid fit (degenerate observation times?)");
        let task_op = TaskOp::new(self.data.task_of.clone(), self.task_kernel.clone());
        let task_factor = task_op.factor();
        let mut rng = Rng::new(seed);
        let skip = SkipOp::build_native(
            vec![SkipComponent::Op(&ski), SkipComponent::Factor(task_factor)],
            self.cfg.rank,
            &mut rng,
        );
        AffineOp { inner: Box::new(skip), scale: 1.0, shift: self.sn2 }
    }

    /// Fast MLL estimate via SKIP + CG + SLQ — the paper's §6 fast path.
    /// The single fit solve stays on plain CG (its allocation-free loop is
    /// the right tool at t = 1); the SLQ log-det underneath batches all
    /// its probes through the fused block-MVM engine (`lanczos_batch`),
    /// which is where this path's multi-RHS traffic actually lives.
    pub fn mll_skip(&self, seed: u64) -> f64 {
        let op = self.build_skip_operator(seed);
        let n = self.data.len() as f64;
        let sol = cg_solve(&op, &self.data.y, self.cfg.cg);
        let fit: f64 = self.data.y.iter().zip(&sol.x).map(|(y, a)| y * a).sum();
        let mut rng = Rng::new(seed ^ 0xA5A5_5A5A_DEAD_BEEF);
        let logdet = slq_logdet(&op, self.cfg.slq, &mut rng);
        -0.5 * fit - 0.5 * logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    /// Analytic dense gradient step data: returns (mll, dL/dB, dL/dD,
    /// dL/dlogℓ, dL/dlogσ_n²).
    ///
    /// With G = ααᵀ − K̂⁻¹ and H = G ∘ K_data, the task-space gradient is
    /// the task-block aggregation S = VᵀHV: dL/dM = ½S, dL/dB = S_sym B,
    /// dL/dD_a = ½S_aa.
    fn dense_grads(&self) -> Result<(f64, Matrix, Vec<f64>, f64, f64)> {
        let n = self.data.len();
        let s = self.task_kernel.num_tasks();
        let khat = self.khat_dense();
        let chol = Cholesky::new_with_jitter(&khat, 1e-10)?;
        let alpha = chol.solve(&self.data.y);
        let kinv = chol.inverse();
        let fit: f64 = self.data.y.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        let mll = -0.5 * fit - 0.5 * chol.logdet()
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

        // S[a,b] = Σ_{i∈a, j∈b} G_ij · K_data,ij.
        let mut s_mat = Matrix::zeros(s, s);
        // dL/dlogℓ accumulator: ½ Σ G_ij (∂K̂/∂logℓ)_ij with
        // ∂k_input/∂logℓ for Matérn/RBF computed by FD on the 1-D kernel
        // (cheap and exact enough; the heavy term G is shared).
        let fd = 1e-5;
        let kern_p = self.input_kernel.with_lengthscale(self.input_kernel.lengthscale * (1.0 + fd));
        let kern_m = self.input_kernel.with_lengthscale(self.input_kernel.lengthscale * (1.0 - fd));
        let mut g_ell = 0.0;
        for i in 0..n {
            for j in 0..n {
                let g = alpha[i] * alpha[j] - kinv.get(i, j);
                let kd = self.input_kernel.eval(self.data.x[i], self.data.x[j]);
                let kt = self
                    .task_kernel
                    .eval(self.data.task_of[i], self.data.task_of[j]);
                let (a, b) = (self.data.task_of[i], self.data.task_of[j]);
                s_mat.set(a, b, s_mat.get(a, b) + g * kd);
                // d k_input / d logℓ ≈ (k₊ − k₋)/(2·fd)
                let dk = (kern_p.eval(self.data.x[i], self.data.x[j])
                    - kern_m.eval(self.data.x[i], self.data.x[j]))
                    / (2.0 * fd);
                g_ell += 0.5 * g * dk * kt;
            }
        }
        // dL/dB = S_sym B (task-space chain rule through M = BBᵀ).
        let mut s_sym = s_mat.clone();
        s_sym.symmetrize();
        let db = s_sym.matmul(&self.task_kernel.b);
        // dL/dD_a = ½ S_aa (δ term only hits i=j task blocks... diagonal of
        // M); chain through softplus-free positive D is handled by caller
        // via log-param. Here raw dL/dD.
        let dd: Vec<f64> = (0..s).map(|a| 0.5 * s_mat.get(a, a)).collect();
        // dL/dlogσ_n² = σ_n²·½·(‖α‖² − tr K̂⁻¹) .
        let aa: f64 = alpha.iter().map(|a| a * a).sum();
        let g_sn2 = self.sn2 * 0.5 * (aa - kinv.trace());
        Ok((mll, db, dd, g_ell, g_sn2))
    }

    /// Train B, D, ℓ, σ_n² with ADAM on the exact dense MLL.
    pub fn fit_dense(&mut self, steps: usize, lr: f64) -> Result<Vec<f64>> {
        let s = self.task_kernel.num_tasks();
        let q = self.task_kernel.b.cols;
        // Parameter vector: [B (s·q), log D (s), log ℓ, log σ_n²].
        let dim = s * q + s + 2;
        let mut adam = Adam::new(dim, lr);
        let mut params = Vec::with_capacity(dim);
        params.extend_from_slice(&self.task_kernel.b.data);
        params.extend(self.task_kernel.diag.iter().map(|d| d.max(1e-8).ln()));
        params.push(self.input_kernel.lengthscale.ln());
        params.push(self.sn2.ln());
        let mut trace = Vec::with_capacity(steps);
        for _ in 0..steps {
            self.unpack_params(&params, s, q);
            let (mll, db, dd, g_ell, g_sn2) = self.dense_grads()?;
            trace.push(mll);
            let mut grad = Vec::with_capacity(dim);
            grad.extend_from_slice(&db.data);
            for a in 0..s {
                // chain: d/d logD = D · d/dD
                grad.push(self.task_kernel.diag[a] * dd[a]);
            }
            grad.push(g_ell);
            grad.push(g_sn2);
            adam.step_ascend(&mut params, &grad);
        }
        self.unpack_params(&params, s, q);
        self.refresh()?;
        Ok(trace)
    }

    fn unpack_params(&mut self, params: &[f64], s: usize, q: usize) {
        self.task_kernel.b = Matrix::from_vec(s, q, params[..s * q].to_vec());
        for a in 0..s {
            self.task_kernel.diag[a] = params[s * q + a].exp();
        }
        self.input_kernel = self
            .input_kernel
            .with_lengthscale(params[s * q + s].exp());
        self.sn2 = params[s * q + s + 1].exp();
    }

    /// Recompute the dense predictive cache α.
    pub fn refresh(&mut self) -> Result<()> {
        let chol = Cholesky::new_with_jitter(&self.khat_dense(), 1e-10)?;
        self.alpha = Some(chol.solve(&self.data.y));
        Ok(())
    }

    /// Predictive mean at (x*, task t) pairs.
    pub fn predict_mean(&self, xt: &[f64], task_t: &[usize]) -> Vec<f64> {
        let alpha = self.alpha.as_ref().expect("call fit/refresh first");
        assert_eq!(xt.len(), task_t.len());
        xt.iter()
            .zip(task_t)
            .map(|(&x, &t)| {
                let mut acc = 0.0;
                for j in 0..self.data.len() {
                    acc += self.input_kernel.eval(x, self.data.x[j])
                        * self.task_kernel.eval(t, self.data.task_of[j])
                        * alpha[j];
                }
                acc
            })
            .collect()
    }
}

// The multi-task property tests (SKIP-vs-dense MLL agreement, `fit_dense`
// task-structure recovery, pooled-baseline comparison, SKIP MVM vs the
// dense covariance) are promoted to rust/tests/mtgp_props.rs so they
// exercise the public API.
