//! Cluster multi-task GP with Gibbs sampling (paper §6).
//!
//! Kernel (paper's display equation):
//!
//! ```text
//! k((x,i),(x′,j)) = k_cluster(x,x′)·δ[λ_i = λ_j] + k_indiv(x,x′)·δ[i = j]
//! ```
//!
//! with Matérn-5/2 `k_cluster`, `k_indiv` and a uniform categorical prior
//! on the cluster assignment λ_i ∈ [1..c]. Both terms are product kernels
//! (data kernel × indicator task kernel), so SKIP accelerates the O(c·s)
//! marginal-likelihood evaluations each Gibbs sweep needs.

use crate::kernels::{Stationary1d, TaskKernel};
use crate::linalg::{Cholesky, Matrix};
use crate::operators::{AffineOp, SkiOp, SkipComponent, SkipOp, SumOp, TaskOp};
use crate::solvers::{cg_solve, slq_logdet, CgConfig, SlqConfig};
use crate::util::Rng;
use crate::Result;

use super::mtgp::MtgpData;

/// Configuration for the cluster-MTGP sampler.
#[derive(Clone, Debug)]
pub struct ClusterMtgpConfig {
    /// Number of latent clusters c.
    pub num_clusters: usize,
    pub grid_m: usize,
    pub rank: usize,
    pub cg: CgConfig,
    pub slq: SlqConfig,
    pub seed: u64,
    /// Use the SKIP fast path for MLL (false → dense Cholesky oracle).
    pub use_skip: bool,
}

impl Default for ClusterMtgpConfig {
    fn default() -> Self {
        ClusterMtgpConfig {
            num_clusters: 3,
            grid_m: 64,
            rank: 15,
            cg: CgConfig { max_iters: 60, tol: 1e-4, ..CgConfig::default() },
            slq: SlqConfig { num_probes: 6, max_rank: 20 },
            seed: 0,
            use_skip: true,
        }
    }
}

/// Cluster-structured multi-task GP.
pub struct ClusterMtgp {
    pub data: MtgpData,
    pub k_cluster: Stationary1d,
    pub k_indiv: Stationary1d,
    /// Amplitude of the cluster-level term.
    pub cluster_var: f64,
    /// Amplitude of the individual term. Kept *below* the cluster
    /// amplitude by default so per-task kernels cannot absorb the
    /// cluster-level offsets (which would wash out the clustering).
    pub indiv_var: f64,
    pub sn2: f64,
    /// Current cluster assignment per task.
    pub assignments: Vec<usize>,
    pub cfg: ClusterMtgpConfig,
}

impl ClusterMtgp {
    pub fn new(data: MtgpData, cfg: ClusterMtgpConfig) -> Self {
        let mut rng = Rng::new(cfg.seed.wrapping_add(23));
        let assignments =
            (0..data.num_tasks).map(|_| rng.below(cfg.num_clusters)).collect();
        ClusterMtgp {
            data,
            k_cluster: Stationary1d::matern52(1.0),
            k_indiv: Stationary1d::matern52(0.5),
            cluster_var: 1.0,
            indiv_var: 0.2,
            sn2: 0.05,
            assignments,
            cfg,
        }
    }

    /// Cluster-membership task kernel for assignment vector `lambda`:
    /// `B = onehot(λ)` (s×c) → `BBᵀ = δ[λ_i = λ_j]`.
    fn cluster_task_kernel(&self, lambda: &[usize]) -> TaskKernel {
        let s = self.data.num_tasks;
        let c = self.cfg.num_clusters;
        let mut b = Matrix::zeros(s, c);
        for (t, &l) in lambda.iter().enumerate() {
            b.set(t, l, 1.0);
        }
        TaskKernel::new(b, vec![0.0; s])
    }

    /// Identity task kernel: `δ[i = j]` over tasks.
    fn indiv_task_kernel(&self) -> TaskKernel {
        TaskKernel::independent(self.data.num_tasks)
    }

    /// Dense K̂ for assignment vector `lambda` (oracle / small n).
    pub fn khat_dense(&self, lambda: &[usize]) -> Matrix {
        let n = self.data.len();
        let mut k = Matrix::from_fn(n, n, |i, j| {
            let (ti, tj) = (self.data.task_of[i], self.data.task_of[j]);
            let mut v = 0.0;
            if lambda[ti] == lambda[tj] {
                v += self.cluster_var * self.k_cluster.eval(self.data.x[i], self.data.x[j]);
            }
            if ti == tj {
                v += self.indiv_var * self.k_indiv.eval(self.data.x[i], self.data.x[j]);
            }
            v
        });
        k.add_diag(self.sn2);
        k
    }

    /// Exact dense MLL for `lambda`.
    pub fn mll_dense(&self, lambda: &[usize]) -> Result<f64> {
        let n = self.data.len() as f64;
        let chol = Cholesky::new_with_jitter(&self.khat_dense(lambda), 1e-10)?;
        let alpha = chol.solve(&self.data.y);
        let fit: f64 = self.data.y.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        Ok(-0.5 * fit - 0.5 * chol.logdet()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Build the SKIP-accelerated covariance operator for `lambda`:
    /// sum of two SKIP products plus noise.
    pub fn build_operator(&self, lambda: &[usize], seed: u64) -> AffineOp {
        let mut rng = Rng::new(seed);
        // Term 1: k_cluster ∘ cluster-membership.
        let ski_c = SkiOp::new(&self.data.x, &self.k_cluster, self.cfg.grid_m)
            .expect("cluster-kernel grid fit (degenerate observation ages?)");
        let fac_c = TaskOp::new(self.data.task_of.clone(), self.cluster_task_kernel(lambda))
            .factor();
        let skip_c = SkipOp::build_native(
            vec![SkipComponent::Op(&ski_c), SkipComponent::Factor(fac_c)],
            self.cfg.rank,
            &mut rng,
        );
        // Term 2: k_indiv ∘ task-identity.
        let ski_i = SkiOp::new(&self.data.x, &self.k_indiv, self.cfg.grid_m)
            .expect("individual-kernel grid fit (degenerate observation ages?)");
        let fac_i =
            TaskOp::new(self.data.task_of.clone(), self.indiv_task_kernel()).factor();
        let skip_i = SkipOp::build_native(
            vec![SkipComponent::Op(&ski_i), SkipComponent::Factor(fac_i)],
            self.cfg.rank,
            &mut rng,
        );
        let sum = SumOp {
            terms: vec![
                Box::new(AffineOp { inner: Box::new(skip_c), scale: self.cluster_var, shift: 0.0 }),
                Box::new(AffineOp { inner: Box::new(skip_i), scale: self.indiv_var, shift: 0.0 }),
            ],
        };
        AffineOp { inner: Box::new(sum), scale: 1.0, shift: self.sn2 }
    }

    /// MLL for `lambda` via the configured path (SKIP or dense).
    pub fn mll(&self, lambda: &[usize], seed: u64) -> f64 {
        if !self.cfg.use_skip {
            return self.mll_dense(lambda).unwrap_or(f64::NEG_INFINITY);
        }
        let op = self.build_operator(lambda, seed);
        let n = self.data.len() as f64;
        let sol = cg_solve(&op, &self.data.y, self.cfg.cg);
        let fit: f64 = self.data.y.iter().zip(&sol.x).map(|(y, a)| y * a).sum();
        let mut rng = Rng::new(seed ^ 0xC1C1_D2D2_E3E3_F4F4);
        let logdet = slq_logdet(&op, self.cfg.slq, &mut rng);
        -0.5 * fit - 0.5 * logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    /// One Gibbs sweep over all task assignments. Returns the number of
    /// assignment changes. Within a sweep all MLL evaluations share the
    /// same probe seed (common random numbers), so the categorical
    /// comparisons are low-variance.
    pub fn gibbs_sweep(&mut self, rng: &mut Rng) -> usize {
        let c = self.cfg.num_clusters;
        let sweep_seed = rng.next_u64();
        let mut changes = 0;
        for t in 0..self.data.num_tasks {
            let mut lambda = self.assignments.clone();
            let mut log_post = Vec::with_capacity(c);
            for a in 0..c {
                lambda[t] = a;
                // Uniform prior over clusters → posterior ∝ likelihood.
                log_post.push(self.mll(&lambda, sweep_seed));
            }
            // Softmax sample.
            let mx = log_post.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let weights: Vec<f64> =
                log_post.iter().map(|&lp| (lp - mx).exp()).collect();
            let new_a = rng.categorical(&weights);
            if new_a != self.assignments[t] {
                changes += 1;
            }
            self.assignments[t] = new_a;
        }
        changes
    }

    /// Run `sweeps` Gibbs sweeps; returns assignment-change counts.
    pub fn run_gibbs(&mut self, sweeps: usize) -> Vec<usize> {
        let mut rng = Rng::new(self.cfg.seed.wrapping_add(101));
        (0..sweeps).map(|_| self.gibbs_sweep(&mut rng)).collect()
    }

    /// Posterior distribution over cluster assignment for one task given
    /// the others fixed (Fig. 3's per-cluster probabilities).
    pub fn cluster_posterior(&self, task: usize, seed: u64) -> Vec<f64> {
        let c = self.cfg.num_clusters;
        let mut lambda = self.assignments.clone();
        let mut log_post = Vec::with_capacity(c);
        for a in 0..c {
            lambda[task] = a;
            log_post.push(self.mll(&lambda, seed));
        }
        let mx = log_post.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = log_post.iter().map(|&lp| (lp - mx).exp()).collect();
        let z: f64 = ws.iter().sum();
        ws.iter().map(|w| w / z).collect()
    }

    /// Dense predictive mean at (x*, task) pairs under current assignments.
    pub fn predict_mean(&self, xt: &[f64], task_t: &[usize]) -> Result<Vec<f64>> {
        let chol = Cholesky::new_with_jitter(&self.khat_dense(&self.assignments), 1e-10)?;
        let alpha = chol.solve(&self.data.y);
        Ok(xt
            .iter()
            .zip(task_t)
            .map(|(&x, &t)| {
                let lt = self.assignments[t];
                let mut acc = 0.0;
                for j in 0..self.data.len() {
                    let tj = self.data.task_of[j];
                    let mut k = 0.0;
                    if self.assignments[tj] == lt {
                        k += self.cluster_var * self.k_cluster.eval(x, self.data.x[j]);
                    }
                    if tj == t {
                        k += self.indiv_var * self.k_indiv.eval(x, self.data.x[j]);
                    }
                    acc += k * alpha[j];
                }
                acc
            })
            .collect())
    }
}

/// Lloyd k-means over the rows of `points` (n×d): seeded start plus
/// `iters` refinement sweeps, fully deterministic for a given `seed`.
/// Returns the k×d centroid matrix. Empty clusters keep their previous
/// centroid, so the result always has k rows. This is the spatial
/// partitioning the serving fleet's shard router uses to assign
/// prediction requests to local experts (the KISS-GP line of work
/// scales by exactly this combination of structured inference and
/// local partitioning).
pub fn spatial_centroids(
    points: &Matrix,
    k: usize,
    iters: usize,
    seed: u64,
) -> Result<Matrix> {
    let (n, d) = (points.rows, points.cols);
    if k == 0 {
        return Err(crate::Error::Grid("k-means needs k >= 1".into()));
    }
    if n == 0 {
        return Err(crate::Error::Grid("k-means needs at least one point".into()));
    }
    // Seed centroids from sampled rows. Duplicate draws are harmless:
    // the duplicate cluster stays empty (ties break low) and keeps its
    // seed point.
    let mut rng = Rng::new(seed.wrapping_add(0x9E37_79B9_7F4A_7C15));
    let mut centroids = Matrix::zeros(k, d);
    for c in 0..k {
        let src = rng.below(n);
        for j in 0..d {
            centroids.set(c, j, points.get(src, j));
        }
    }
    let mut assign = vec![0usize; n];
    for sweep in 0..iters {
        let mut changed = false;
        for (i, a) in assign.iter_mut().enumerate() {
            let nearest = nearest_centroid(points.row(i), &centroids);
            if nearest != *a || sweep == 0 {
                changed = true;
            }
            *a = nearest;
        }
        if !changed {
            break;
        }
        let mut sums = Matrix::zeros(k, d);
        let mut counts = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            counts[c] += 1;
            for j in 0..d {
                sums.set(c, j, sums.get(c, j) + points.get(i, j));
            }
        }
        for (c, &count) in counts.iter().enumerate() {
            if count == 0 {
                continue; // empty cluster: keep the previous centroid
            }
            for j in 0..d {
                centroids.set(c, j, sums.get(c, j) / count as f64);
            }
        }
    }
    Ok(centroids)
}

/// Index of the centroid (row of `centroids`) nearest to `x` in squared
/// Euclidean distance. Ties break toward the lower index, so routing
/// on the boundary is still deterministic.
pub fn nearest_centroid(x: &[f64], centroids: &Matrix) -> usize {
    debug_assert_eq!(x.len(), centroids.cols);
    let mut best = 0usize;
    let mut best_d2 = f64::INFINITY;
    for c in 0..centroids.rows {
        let mut d2 = 0.0;
        for (xj, cj) in x.iter().zip(centroids.row(c)) {
            let diff = xj - cj;
            d2 += diff * diff;
        }
        if d2 < best_d2 {
            best_d2 = d2;
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three true clusters with distinct mean curves.
    fn clustered_tasks(
        tasks_per_cluster: usize,
        per_task: usize,
        seed: u64,
    ) -> (MtgpData, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut task_of = Vec::new();
        let mut truth = Vec::new();
        let s = 3 * tasks_per_cluster;
        for t in 0..s {
            let c = t / tasks_per_cluster;
            truth.push(c);
            // Clusters differ in both level and shape so the cluster
            // kernel, not the individual kernel, explains the signal.
            let (level, freq) = match c {
                0 => (2.5, 0.8),
                1 => (0.0, 1.6),
                _ => (-2.5, 1.2),
            };
            for _ in 0..per_task {
                let xi = rng.uniform_in(0.0, 3.0);
                x.push(xi);
                y.push(level + 0.6 * (xi * freq).sin() + 0.05 * rng.normal());
                task_of.push(t);
            }
        }
        (MtgpData { x, y, task_of, num_tasks: s }, truth)
    }

    /// Cluster-label-invariant agreement: fraction of task pairs whose
    /// co-membership matches the truth.
    fn pair_agreement(a: &[usize], b: &[usize]) -> f64 {
        let s = a.len();
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..s {
            for j in (i + 1)..s {
                total += 1;
                if (a[i] == a[j]) == (b[i] == b[j]) {
                    agree += 1;
                }
            }
        }
        agree as f64 / total as f64
    }

    #[test]
    fn skip_mll_tracks_dense_mll() {
        let (data, truth) = clustered_tasks(2, 8, 1);
        let cfg = ClusterMtgpConfig {
            rank: 30,
            cg: CgConfig { max_iters: 150, tol: 1e-6, ..CgConfig::default() },
            slq: SlqConfig { num_probes: 20, max_rank: 30 },
            ..Default::default()
        };
        let model = ClusterMtgp::new(data, cfg);
        let dense = model.mll_dense(&truth).unwrap();
        let fast = model.mll(&truth, 5);
        let rel = (fast - dense).abs() / dense.abs();
        assert!(rel < 0.08, "skip {fast} dense {dense} rel {rel}");
    }

    #[test]
    fn mll_prefers_true_clustering() {
        let (data, truth) = clustered_tasks(3, 8, 2);
        let model = ClusterMtgp::new(data, ClusterMtgpConfig::default());
        let good = model.mll_dense(&truth).unwrap();
        // Scrambled assignment.
        let bad_lambda: Vec<usize> = (0..truth.len()).map(|t| t % 3).collect();
        let bad = model.mll_dense(&bad_lambda).unwrap();
        assert!(good > bad, "true-cluster MLL {good} ≤ scrambled {bad}");
    }

    #[test]
    fn gibbs_recovers_clusters_dense() {
        let (data, truth) = clustered_tasks(3, 8, 3);
        let cfg = ClusterMtgpConfig { use_skip: false, ..Default::default() };
        let mut model = ClusterMtgp::new(data, cfg);
        model.run_gibbs(12);
        let agreement = pair_agreement(&model.assignments, &truth);
        assert!(agreement > 0.8, "agreement {agreement}");
    }

    #[test]
    fn gibbs_recovers_clusters_skip() {
        let (data, truth) = clustered_tasks(3, 8, 4);
        let cfg = ClusterMtgpConfig { use_skip: true, ..Default::default() };
        let mut model = ClusterMtgp::new(data, cfg);
        model.run_gibbs(8);
        let agreement = pair_agreement(&model.assignments, &truth);
        assert!(agreement > 0.8, "agreement {agreement}");
    }

    #[test]
    fn posterior_concentrates_with_more_data() {
        // Fig. 3's qualitative claim: more observed measurements → more
        // confident cluster posterior for a new task.
        let (data, truth) = clustered_tasks(3, 10, 5);
        let cfg = ClusterMtgpConfig { use_skip: false, ..Default::default() };
        let mut model = ClusterMtgp::new(data, cfg);
        model.assignments = truth.clone();
        // Task 0 (cluster 0): posterior with all its data.
        let post = model.cluster_posterior(0, 9);
        assert!(post[truth[0]] > 0.5, "posterior {post:?}");
    }

    #[test]
    fn kmeans_recovers_separated_blobs() {
        let centers = [[-4.0, -4.0], [4.0, -4.0], [0.0, 5.0]];
        let n_per = 40;
        let mut rng = Rng::new(11);
        let pts = Matrix::from_fn(3 * n_per, 2, |i, j| {
            centers[i / n_per][j] + 0.3 * rng.normal()
        });
        let cent = spatial_centroids(&pts, 3, 25, 0).unwrap();
        assert_eq!((cent.rows, cent.cols), (3, 2));
        // Every true center has a recovered centroid within 1.0.
        for c in &centers {
            let best = (0..3)
                .map(|r| {
                    let row = cent.row(r);
                    let (dx, dy) = (row[0] - c[0], row[1] - c[1]);
                    (dx * dx + dy * dy).sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 1.0, "center {c:?} unmatched (nearest {best})");
        }
        // All points of one blob route to the same centroid.
        for blob in 0..3 {
            let first = nearest_centroid(pts.row(blob * n_per), &cent);
            for i in 1..n_per {
                assert_eq!(
                    nearest_centroid(pts.row(blob * n_per + i), &cent),
                    first,
                    "blob {blob} split"
                );
            }
        }
    }

    #[test]
    fn kmeans_is_deterministic_and_total() {
        let mut rng = Rng::new(12);
        let pts = Matrix::from_fn(17, 3, |_, _| rng.uniform_in(-1.0, 1.0));
        let a = spatial_centroids(&pts, 5, 10, 42).unwrap();
        let b = spatial_centroids(&pts, 5, 10, 42).unwrap();
        assert_eq!(a.data, b.data, "same seed must reproduce centroids");
        // k > n still yields k finite centroids (duplicates allowed) and
        // nearest_centroid stays in range.
        let many = spatial_centroids(&pts, 24, 4, 7).unwrap();
        assert_eq!(many.rows, 24);
        assert!(many.data.iter().all(|v| v.is_finite()));
        for i in 0..pts.rows {
            assert!(nearest_centroid(pts.row(i), &many) < 24);
        }
        assert!(spatial_centroids(&pts, 0, 4, 7).is_err());
    }
}
