//! ADAM optimizer (Kingma & Ba, 2015) — the paper trains all
//! hyperparameters "with ADAM using default optimization parameters".
//!
//! ADAM's small, momentum-damped steps are what make the solver layer's
//! warm starts effective: consecutive `MvmGp::mll_grad` calls see nearly
//! the same covariance, so each step's y-solve is seeded with the
//! previous α and converges in a handful of (preconditioned) CG
//! iterations instead of a cold Krylov build-up — see
//! `crate::solvers::cg::cg_solve_with` and `docs/SOLVERS.md`.

/// ADAM state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    /// Default β₁=0.9, β₂=0.999, ε=1e-8.
    pub fn new(dim: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Ascend step: `params ← params + update(grad)` (we maximize MLL).
    pub fn step_ascend(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Descend step (minimization).
    pub fn step_descend(&mut self, params: &mut [f64], grad: &[f64]) {
        let neg: Vec<f64> = grad.iter().map(|g| -g).collect();
        self.step_ascend(params, &neg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maximizes_concave_quadratic() {
        // f(x) = -(x-3)², grad = -2(x-3); ascend should reach x ≈ 3.
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        for _ in 0..500 {
            let g = -2.0 * (x[0] - 3.0);
            adam.step_ascend(&mut x, &[g]);
        }
        assert!((x[0] - 3.0).abs() < 0.05, "x={}", x[0]);
    }

    #[test]
    fn minimizes_convex_quadratic() {
        let mut adam = Adam::new(2, 0.05);
        let mut x = vec![5.0, -4.0];
        for _ in 0..2000 {
            let g = vec![2.0 * x[0], 2.0 * (x[1] + 1.0)];
            adam.step_descend(&mut x, &g);
        }
        assert!(x[0].abs() < 0.05);
        assert!((x[1] + 1.0).abs() < 0.05);
    }

    #[test]
    fn first_step_is_lr_sized() {
        let mut adam = Adam::new(1, 0.1);
        let mut x = vec![0.0];
        adam.step_ascend(&mut x, &[123.0]);
        // ADAM's first step magnitude ≈ lr regardless of gradient scale.
        assert!((x[0] - 0.1).abs() < 1e-6);
    }
}
