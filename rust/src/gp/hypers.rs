//! GP hyperparameters.
//!
//! All models share the same parameterization: a shared RBF lengthscale ℓ,
//! an output scale σ_f², and a noise variance σ_n², stored in log space so
//! unconstrained gradient steps keep them positive. (Paper §5: "All models
//! use the RBF kernel", trained with ADAM.)

/// Log-space GP hyperparameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpHypers {
    /// log lengthscale ℓ (shared across dimensions).
    pub log_ell: f64,
    /// log output scale σ_f².
    pub log_sf2: f64,
    /// log noise variance σ_n².
    pub log_sn2: f64,
}

impl GpHypers {
    /// Sensible default init for z-scored data.
    pub fn default_init() -> Self {
        GpHypers {
            log_ell: 0.0,   // ℓ = 1
            log_sf2: 0.0,   // σ_f² = 1
            log_sn2: -2.0,  // σ_n² ≈ 0.135
        }
    }

    /// Median-distance heuristic init: for inputs ~U[-1,1]^d the expected
    /// squared distance is 2d/3, so ℓ₀ = √(2d/3) starts the product kernel
    /// in a smooth (low effective rank) regime. This matters for SKIP:
    /// rank(A∘B) ≤ rank(A)·rank(B) (paper §7), so a too-short initial ℓ
    /// makes the rank-r merge tree a poor approximation before training
    /// has a chance to lengthen it.
    pub fn init_for_dim(d: usize) -> Self {
        let ell0 = (2.0 * d as f64 / 3.0).sqrt().max(1.0);
        GpHypers { log_ell: ell0.ln(), log_sf2: 0.0, log_sn2: -2.0 }
    }

    pub fn new(ell: f64, sf2: f64, sn2: f64) -> Self {
        assert!(ell > 0.0 && sf2 > 0.0 && sn2 > 0.0);
        GpHypers { log_ell: ell.ln(), log_sf2: sf2.ln(), log_sn2: sn2.ln() }
    }

    pub fn ell(&self) -> f64 {
        self.log_ell.exp()
    }

    pub fn sf2(&self) -> f64 {
        self.log_sf2.exp()
    }

    pub fn sn2(&self) -> f64 {
        self.log_sn2.exp()
    }

    /// Flatten for the optimizer.
    pub fn to_vec(&self) -> Vec<f64> {
        vec![self.log_ell, self.log_sf2, self.log_sn2]
    }

    /// Rebuild from the optimizer's parameter vector.
    pub fn from_vec(v: &[f64]) -> Self {
        assert_eq!(v.len(), 3);
        GpHypers { log_ell: v[0], log_sf2: v[1], log_sn2: v[2] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let h = GpHypers::new(0.5, 2.0, 0.01);
        let v = h.to_vec();
        let h2 = GpHypers::from_vec(&v);
        assert_eq!(h, h2);
        assert!((h.ell() - 0.5).abs() < 1e-12);
        assert!((h.sf2() - 2.0).abs() < 1e-12);
        assert!((h.sn2() - 0.01).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive() {
        GpHypers::new(-1.0, 1.0, 1.0);
    }
}
