//! SGPR: sparse variational GP regression (Titsias 2009; Hensman et al.
//! 2013) — the paper's main baseline ("SGPR … implemented in GPflow",
//! Table 1 columns 2–4; Table 2 row "SVGP": O(nm² + m³ + dnm)).
//!
//! We implement the collapsed evidence lower bound with m inducing points
//! chosen as a random subset of the training inputs:
//!
//! ```text
//! ELBO = log N(y | 0, Q_nn + σ²I) − 1/(2σ²)·tr(K_nn − Q_nn),
//! Q_nn = K_nm K_mm⁻¹ K_mn
//! ```
//!
//! evaluated in O(nm²) through Cholesky factors of `K_mm` and
//! `B = I + A Aᵀ`, `A = σ⁻¹ L⁻¹ K_mn`.

use super::adam::Adam;
use super::hypers::GpHypers;
use crate::kernels::ProductKernel;
use crate::linalg::{Cholesky, Matrix};
use crate::util::Rng;
use crate::Result;

/// Sparse variational GP with a shared-lengthscale RBF kernel.
pub struct Sgpr {
    pub xs: Matrix,
    pub ys: Vec<f64>,
    pub hypers: GpHypers,
    /// Inducing inputs Z (m × d).
    pub z: Matrix,
    cache: Option<PredictCache>,
}

struct PredictCache {
    /// L from K_mm = L Lᵀ.
    l: Cholesky,
    /// LB from B = I + A Aᵀ = LB LBᵀ.
    lb: Cholesky,
    /// c = LB⁻¹ A y / σ (m).
    c: Vec<f64>,
}

impl Sgpr {
    /// Choose m inducing points as a random training subset.
    pub fn new(xs: Matrix, ys: Vec<f64>, hypers: GpHypers, m: usize, seed: u64) -> Self {
        assert_eq!(xs.rows, ys.len());
        let m = m.min(xs.rows);
        let mut rng = Rng::new(seed);
        let mut idx: Vec<usize> = (0..xs.rows).collect();
        rng.shuffle(&mut idx);
        let z = Matrix::from_fn(m, xs.cols, |i, j| xs.get(idx[i], j));
        Sgpr { xs, ys, hypers, z, cache: None }
    }

    fn kernel(&self, h: &GpHypers) -> ProductKernel {
        ProductKernel::rbf(self.xs.cols, h.ell(), h.sf2())
    }

    /// Shared factorization work for bound + prediction.
    fn factorize(&self, h: &GpHypers) -> Result<(PredictCache, f64)> {
        let n = self.xs.rows;
        let m = self.z.rows;
        let sn2 = h.sn2();
        let kern = self.kernel(h);
        let mut kmm = kern.gram_sym(&self.z);
        kmm.add_diag(1e-8 * h.sf2().max(1.0)); // jitter
        let l = Cholesky::new_with_jitter(&kmm, 1e-10)?;
        let kmn = kern.gram(&self.z, &self.xs); // m × n
        // A = σ⁻¹ L⁻¹ K_mn  (m × n): one blocked forward substitution for
        // all n columns at once (batched multi-RHS engine).
        let sigma = sn2.sqrt();
        let mut a = l.solve_lower_mat(&kmn);
        for v in a.data.iter_mut() {
            *v /= sigma;
        }
        // B = I + A Aᵀ (m×m).
        let mut b = a.matmul_t(&a);
        b.add_diag(1.0);
        let lb = Cholesky::new_with_jitter(&b, 1e-10)?;
        // c = LB⁻¹ (A y) / σ.
        let ay = a.matvec(&self.ys);
        let ay_scaled: Vec<f64> = ay.iter().map(|v| v / sigma).collect();
        let c = lb.solve_lower(&ay_scaled);

        // ELBO (collapsed bound):
        // −n/2 log2π − Σ log diag(LB) − n/2 logσ² − ‖y‖²/(2σ²) + ‖c‖²/2
        // − (tr(K_nn) − tr(AAᵀ)σ²) / (2σ²)
        let yy: f64 = self.ys.iter().map(|y| y * y).sum();
        let cc: f64 = c.iter().map(|v| v * v).sum();
        let log_diag_lb: f64 = (0..m).map(|i| lb.l.get(i, i).ln()).sum();
        // tr(K_nn) = n σ_f² for stationary kernels.
        let tr_knn = n as f64 * h.sf2();
        // tr(Q_nn)/σ² = tr(AAᵀ) — A already carries 1/σ.
        let tr_aat: f64 = a.data.iter().map(|v| v * v).sum();
        let elbo = -0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln()
            - log_diag_lb
            - 0.5 * n as f64 * sn2.ln()
            - 0.5 * yy / sn2
            + 0.5 * cc
            - 0.5 * (tr_knn / sn2 - tr_aat);
        Ok((PredictCache { l, lb, c }, elbo))
    }

    /// The collapsed variational bound (deterministic).
    pub fn elbo(&self, h: &GpHypers) -> Result<f64> {
        Ok(self.factorize(h)?.1)
    }

    /// Train hyperparameters with ADAM on the bound; gradients by central
    /// finite differences (the bound is deterministic, so plain FD is
    /// exact up to O(h²)). Refreshes the predictive cache.
    pub fn fit(&mut self, steps: usize, lr: f64) -> Result<Vec<f64>> {
        let mut adam = Adam::new(3, lr);
        let mut params = self.hypers.to_vec();
        let mut trace = Vec::with_capacity(steps);
        let fd = 1e-4;
        for _ in 0..steps {
            let h = GpHypers::from_vec(&params);
            let l0 = self.elbo(&h)?;
            trace.push(l0);
            let mut grad = vec![0.0; 3];
            for i in 0..3 {
                let mut vp = params.clone();
                vp[i] += fd;
                let mut vm = params.clone();
                vm[i] -= fd;
                let lp = self.elbo(&GpHypers::from_vec(&vp))?;
                let lm = self.elbo(&GpHypers::from_vec(&vm))?;
                grad[i] = (lp - lm) / (2.0 * fd);
            }
            adam.step_ascend(&mut params, &grad);
        }
        self.hypers = GpHypers::from_vec(&params);
        self.refresh()?;
        Ok(trace)
    }

    /// Recompute the predictive cache.
    pub fn refresh(&mut self) -> Result<()> {
        let (cache, _) = self.factorize(&self.hypers)?;
        self.cache = Some(cache);
        Ok(())
    }

    /// SGPR predictive mean `μ* = K_{*m} L⁻ᵀ LB⁻ᵀ c`:
    /// with σ²K_mm + K_mn K_nm = σ² L B Lᵀ and c = LB⁻¹ A y / σ, Titsias's
    /// μ* = K_{*m}(σ²K_mm + K_mn K_nm)⁻¹ K_mn y reduces to exactly this.
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        let cache = self.cache.as_ref().expect("call fit/refresh first");
        let kern = self.kernel(&self.hypers);
        let kts = kern.gram(&self.z, xtest); // m × n*
        // Both triangular solves run blocked over the whole test batch.
        let linv_k = cache.l.solve_lower_mat(&kts);
        let lbinv = cache.lb.solve_lower_mat(&linv_k);
        // μ* per test point: ⟨LB⁻¹L⁻¹k*, c⟩ — accumulate row-wise so the
        // inner loop walks contiguous memory.
        let mut out = vec![0.0; xtest.rows];
        for (i, &ci) in cache.c.iter().enumerate() {
            for (o, &v) in out.iter_mut().zip(lbinv.row(i)) {
                *o += ci * v;
            }
        }
        out
    }

    /// Number of inducing points.
    pub fn num_inducing(&self) -> usize {
        self.z.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::exact::ExactGp;
    use crate::util::{mae, Rng};

    fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let f = |row: &[f64]| -> f64 {
            row.iter().map(|&x| (2.0 * x).sin()).sum()
        };
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
        let xt = Matrix::from_fn(40, d, |_, _| rng.uniform_in(-0.9, 0.9));
        let yt: Vec<f64> = (0..40).map(|i| f(xt.row(i))).collect();
        (xs, ys, xt, yt)
    }

    #[test]
    fn elbo_lower_bounds_exact_mll() {
        let (xs, ys, _, _) = toy(100, 2, 1);
        let h = GpHypers::new(0.8, 1.0, 0.1);
        let exact = ExactGp::new(xs.clone(), ys.clone(), h).mll(&h).unwrap();
        let sgpr = Sgpr::new(xs, ys, h, 40, 0);
        let elbo = sgpr.elbo(&h).unwrap();
        assert!(elbo <= exact + 1e-6, "elbo {elbo} must lower-bound mll {exact}");
        // Not vacuously loose either.
        assert!(elbo > exact - 0.5 * exact.abs().max(50.0));
    }

    #[test]
    fn elbo_tightens_with_more_inducing() {
        let (xs, ys, _, _) = toy(120, 2, 2);
        let h = GpHypers::new(0.8, 1.0, 0.1);
        let e1 = Sgpr::new(xs.clone(), ys.clone(), h, 10, 0).elbo(&h).unwrap();
        let e2 = Sgpr::new(xs.clone(), ys.clone(), h, 60, 0).elbo(&h).unwrap();
        assert!(e2 >= e1 - 1e-6, "m=60 elbo {e2} < m=10 elbo {e1}");
    }

    #[test]
    fn all_points_inducing_recovers_exact_predictions() {
        let (xs, ys, xt, _) = toy(80, 1, 3);
        let h = GpHypers::new(0.6, 1.0, 0.05);
        let mut exact = ExactGp::new(xs.clone(), ys.clone(), h);
        exact.refresh().unwrap();
        let mut sgpr = Sgpr::new(xs, ys, h, 80, 0);
        sgpr.refresh().unwrap();
        let pe = exact.predict_mean(&xt);
        let ps = sgpr.predict_mean(&xt);
        assert!(mae(&pe, &ps) < 1e-3, "mae {}", mae(&pe, &ps));
    }

    #[test]
    fn fit_improves_bound() {
        let (xs, ys, _, _) = toy(100, 2, 4);
        let mut sgpr = Sgpr::new(xs, ys, GpHypers::new(3.0, 0.5, 0.5), 30, 0);
        let trace = sgpr.fit(20, 0.1).unwrap();
        assert!(trace.last().unwrap() > trace.first().unwrap());
    }

    #[test]
    fn regression_quality() {
        let (xs, ys, xt, yt) = toy(200, 2, 5);
        let mut sgpr = Sgpr::new(xs, ys, GpHypers::new(0.7, 1.0, 0.05), 60, 0);
        sgpr.refresh().unwrap();
        let pred = sgpr.predict_mean(&xt);
        assert!(mae(&pred, &yt) < 0.15, "mae {}", mae(&pred, &yt));
    }
}
