//! Exact GP regression via Cholesky — the paper's "Full GP" baseline
//! (Table 1, first column; complexity O(n³), Table 2 first row).

use super::adam::Adam;
use super::hypers::GpHypers;
use crate::kernels::ProductKernel;
use crate::linalg::{Cholesky, Matrix};
use crate::Result;

/// Exact GP with **gradient observations** via the dense derivative
/// kernel `[[K, ∂K], [∂K, ∂²K]]` (D-SKI's O((n(1+d))³) oracle): every
/// training point contributes its value and its d partial derivatives,
/// interleaved in [`crate::kernels::deriv_layout`] row order. This is
/// the reference the structured D-SKI path
/// ([`crate::gp::MvmGp::new_with_grads`]) is held to in the property
/// tests — exactly the role [`ExactGp`] plays for the value-only models.
pub struct ExactGradGp {
    pub xs: Matrix,
    pub ys: Vec<f64>,
    /// Gradient observations, n × d (row i = ∇y at xs row i).
    pub grads: Matrix,
    pub hypers: GpHypers,
    /// Cached α = K̂_ext⁻¹ (y, ∇y) after `refresh`, length n·(1+d).
    alpha: Option<Vec<f64>>,
    chol: Option<Cholesky>,
}

impl ExactGradGp {
    pub fn new(xs: Matrix, ys: Vec<f64>, grads: Matrix, hypers: GpHypers) -> Self {
        assert_eq!(xs.rows, ys.len());
        assert_eq!(grads.rows, xs.rows, "one gradient row per point");
        assert_eq!(grads.cols, xs.cols, "gradient dimensionality");
        ExactGradGp { xs, ys, grads, hypers, alpha: None, chol: None }
    }

    fn kernel(&self) -> ProductKernel {
        ProductKernel::rbf(self.xs.cols, self.hypers.ell(), self.hypers.sf2())
    }

    /// The interleaved `(y, ∇y)` target vector, length n·(1+d).
    pub fn targets(&self) -> Vec<f64> {
        let d = self.xs.cols;
        let mut t = Vec::with_capacity(self.ys.len() * (1 + d));
        for (i, &y) in self.ys.iter().enumerate() {
            t.push(y);
            t.extend_from_slice(self.grads.row(i));
        }
        t
    }

    /// Solve the dense extended system and cache (α, Cholesky).
    pub fn refresh(&mut self) -> Result<()> {
        let mask = vec![true; self.xs.rows];
        let mut khat = self.kernel().gram_deriv_sym(&self.xs, &mask);
        khat.add_diag(self.hypers.sn2());
        let chol = Cholesky::new_with_jitter(&khat, 0.0)?;
        self.alpha = Some(chol.solve(&self.targets()));
        self.chol = Some(chol);
        Ok(())
    }

    /// Cached extended solve (None before `refresh`).
    pub fn alpha(&self) -> Option<&[f64]> {
        self.alpha.as_deref()
    }

    /// Predictive mean: value cross-covariances against every extended
    /// training row.
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        let alpha = self.alpha.as_ref().expect("call refresh first");
        let kern = self.kernel();
        let train_mask = vec![true; self.xs.rows];
        let test_mask = vec![false; xtest.rows];
        let kx = kern.gram_deriv(xtest, &test_mask, &self.xs, &train_mask);
        kx.matvec(alpha)
    }

    /// Gradient of the predictive mean (n* × d): the test side of the
    /// derivative kernel against the cached extended α.
    pub fn predict_grad(&self, xtest: &Matrix) -> Matrix {
        let alpha = self.alpha.as_ref().expect("call refresh first");
        let d = self.xs.cols;
        let kern = self.kernel();
        let train_mask = vec![true; self.xs.rows];
        let test_mask = vec![true; xtest.rows];
        // n*(1+d) × N in interleaved order: row j(1+d) is query j's
        // value, rows j(1+d)+1+a its gradient components.
        let kx = kern.gram_deriv(xtest, &test_mask, &self.xs, &train_mask);
        Matrix::from_fn(xtest.rows, d, |j, a| {
            let row = kx.row(j * (1 + d) + 1 + a);
            row.iter().zip(alpha).map(|(k, al)| k * al).sum()
        })
    }

    /// Latent predictive variance of the value at test points, under the
    /// extended system (gradient observations tighten it).
    pub fn predict_var(&self, xtest: &Matrix) -> Vec<f64> {
        let chol = self.chol.as_ref().expect("call refresh first");
        let kern = self.kernel();
        let train_mask = vec![true; self.xs.rows];
        let test_mask = vec![false; xtest.rows];
        let kx = kern.gram_deriv(xtest, &test_mask, &self.xs, &train_mask); // n* × N
        let sol = chol.solve_mat(&kx.transpose()); // N × n*
        let mut out = Vec::with_capacity(xtest.rows);
        for i in 0..xtest.rows {
            let ki = kx.row(i);
            let mut reduce = 0.0;
            for (j, &k) in ki.iter().enumerate() {
                reduce += k * sol.get(j, i);
            }
            out.push((kern.outputscale - reduce).max(1e-12));
        }
        out
    }
}

/// Exact (Cholesky) GP with shared-lengthscale RBF kernel.
pub struct ExactGp {
    pub xs: Matrix,
    pub ys: Vec<f64>,
    pub hypers: GpHypers,
    /// Cached α = K̂⁻¹ y after `fit`/`refresh`.
    alpha: Option<Vec<f64>>,
    chol: Option<Cholesky>,
}

impl ExactGp {
    pub fn new(xs: Matrix, ys: Vec<f64>, hypers: GpHypers) -> Self {
        assert_eq!(xs.rows, ys.len());
        ExactGp { xs, ys, hypers, alpha: None, chol: None }
    }

    fn kernel(&self, h: &GpHypers) -> ProductKernel {
        ProductKernel::rbf(self.xs.cols, h.ell(), h.sf2())
    }

    /// K̂ = K + σ_n² I, densely.
    fn khat(&self, h: &GpHypers) -> Matrix {
        let mut k = self.kernel(h).gram_sym(&self.xs);
        k.add_diag(h.sn2());
        k
    }

    /// Exact marginal log likelihood (Eq. 3).
    pub fn mll(&self, h: &GpHypers) -> Result<f64> {
        let n = self.ys.len() as f64;
        let chol = Cholesky::new_with_jitter(&self.khat(h), 0.0)?;
        let alpha = chol.solve(&self.ys);
        let fit: f64 = self.ys.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        Ok(-0.5 * fit - 0.5 * chol.logdet() - 0.5 * n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Analytic MLL gradient wrt (log ℓ, log σ_f², log σ_n²):
    /// dL/dθ = ½ tr((ααᵀ − K̂⁻¹) ∂K̂/∂θ).
    pub fn mll_grad(&self, h: &GpHypers) -> Result<(f64, Vec<f64>)> {
        let n = self.xs.rows;
        let khat = self.khat(h);
        let chol = Cholesky::new_with_jitter(&khat, 0.0)?;
        let alpha = chol.solve(&self.ys);
        let kinv = chol.inverse();
        let fit: f64 = self.ys.iter().zip(&alpha).map(|(y, a)| y * a).sum();
        let mll = -0.5 * fit
            - 0.5 * chol.logdet()
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

        // K (kernel part, no noise).
        let k = self.kernel(h).gram_sym(&self.xs);
        let ell2 = h.ell() * h.ell();
        // ∂K/∂logℓ = K ∘ S, S_ij = ‖x_i − x_j‖²/ℓ².
        let dk_ell = Matrix::from_fn(n, n, |i, j| {
            let (xi, xj) = (self.xs.row(i), self.xs.row(j));
            let sq: f64 = xi.iter().zip(xj).map(|(a, b)| (a - b) * (a - b)).sum();
            k.get(i, j) * sq / ell2
        });
        // ∂K̂/∂logσ_f² = K; ∂K̂/∂logσ_n² = σ_n² I.
        let grad_for = |dk: &Matrix| -> f64 {
            // ½ αᵀ dK α − ½ tr(K̂⁻¹ dK)
            let da = dk.matvec(&alpha);
            let quad: f64 = alpha.iter().zip(&da).map(|(a, b)| a * b).sum();
            let mut tr = 0.0;
            for i in 0..n {
                let (ki, di) = (kinv.row(i), dk.row(i));
                for (a, b) in ki.iter().zip(di) {
                    tr += a * b;
                }
            }
            0.5 * quad - 0.5 * tr
        };
        let g_ell = grad_for(&dk_ell);
        let g_sf2 = grad_for(&k);
        // Noise: dK̂ = σ_n² I → closed form.
        let aa: f64 = alpha.iter().map(|a| a * a).sum();
        let g_sn2 = h.sn2() * (0.5 * aa - 0.5 * kinv.trace());
        Ok((mll, vec![g_ell, g_sf2, g_sn2]))
    }

    /// Train hyperparameters by ADAM on the exact MLL. Returns the MLL
    /// trace. Also refreshes the predictive cache.
    pub fn fit(&mut self, steps: usize, lr: f64) -> Result<Vec<f64>> {
        let mut adam = Adam::new(3, lr);
        let mut params = self.hypers.to_vec();
        let mut trace = Vec::with_capacity(steps);
        for _ in 0..steps {
            let h = GpHypers::from_vec(&params);
            let (mll, grad) = self.mll_grad(&h)?;
            trace.push(mll);
            adam.step_ascend(&mut params, &grad);
        }
        self.hypers = GpHypers::from_vec(&params);
        self.refresh()?;
        Ok(trace)
    }

    /// Recompute the predictive cache (Cholesky + α) for current hypers.
    pub fn refresh(&mut self) -> Result<()> {
        let chol = Cholesky::new_with_jitter(&self.khat(&self.hypers), 0.0)?;
        self.alpha = Some(chol.solve(&self.ys));
        self.chol = Some(chol);
        Ok(())
    }

    /// Cached solve `α = K̂⁻¹y` (None before `fit`/`refresh`). The serving
    /// layer reads this when freezing a model into a snapshot.
    pub fn alpha(&self) -> Option<&[f64]> {
        self.alpha.as_deref()
    }

    /// Cached Cholesky factor of K̂ (None before `fit`/`refresh`); the
    /// exact inverse root `L⁻ᵀ` behind `serve::cache::inverse_root_exact`.
    pub fn cholesky(&self) -> Option<&Cholesky> {
        self.chol.as_ref()
    }

    /// Predictive mean at test points (Eq. 1, zero prior mean).
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        let alpha = self.alpha.as_ref().expect("call fit/refresh first");
        let kx = self.kernel(&self.hypers).gram(xtest, &self.xs);
        kx.matvec(alpha)
    }

    /// Predictive variance at test points (Eq. 2), including noise-free
    /// latent variance only.
    ///
    /// Batched: all n* cross-covariance columns go through one blocked
    /// triangular solve ([`Cholesky::solve_mat`]) — `L` streams through
    /// cache once for the whole test block instead of once per point.
    pub fn predict_var(&self, xtest: &Matrix) -> Vec<f64> {
        let chol = self.chol.as_ref().expect("call fit/refresh first");
        let kern = self.kernel(&self.hypers);
        let kx = kern.gram(xtest, &self.xs); // n* × n
        let sol = chol.solve_mat(&kx.transpose()); // n × n*
        let mut out = Vec::with_capacity(xtest.rows);
        for i in 0..xtest.rows {
            let ki = kx.row(i);
            let mut reduce = 0.0;
            for (j, &k) in ki.iter().enumerate() {
                reduce += k * sol.get(j, i);
            }
            out.push((kern.outputscale - reduce).max(1e-12));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mae, Rng};

    /// y = sin(2x) + noise on [0, 3].
    fn toy_1d(n: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs = Matrix::from_fn(n, 1, |_, _| rng.uniform_in(0.0, 3.0));
        let ys: Vec<f64> = (0..n)
            .map(|i| (2.0 * xs.get(i, 0)).sin() + 0.05 * rng.normal())
            .collect();
        (xs, ys)
    }

    #[test]
    fn interpolates_smooth_function() {
        let (xs, ys) = toy_1d(60, 1);
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.5, 1.0, 0.01));
        gp.refresh().unwrap();
        let xt = Matrix::from_fn(20, 1, |i, _| 0.1 + i as f64 * 0.14);
        let pred = gp.predict_mean(&xt);
        let truth: Vec<f64> = (0..20).map(|i| (2.0 * xt.get(i, 0)).sin()).collect();
        assert!(mae(&pred, &truth) < 0.05, "mae {}", mae(&pred, &truth));
    }

    #[test]
    fn fit_improves_mll() {
        let (xs, ys) = toy_1d(40, 2);
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(3.0, 0.5, 0.5));
        let trace = gp.fit(30, 0.1).unwrap();
        assert!(
            trace.last().unwrap() > trace.first().unwrap(),
            "MLL should increase: {:?} → {:?}",
            trace.first(),
            trace.last()
        );
    }

    #[test]
    fn analytic_grad_matches_finite_difference() {
        let (xs, ys) = toy_1d(25, 3);
        let gp = ExactGp::new(xs, ys, GpHypers::default_init());
        let h0 = GpHypers::new(0.8, 1.2, 0.05);
        let (_, grad) = gp.mll_grad(&h0).unwrap();
        let eps = 1e-5;
        let mut v = h0.to_vec();
        for (i, g) in grad.iter().enumerate() {
            v[i] += eps;
            let lp = gp.mll(&GpHypers::from_vec(&v)).unwrap();
            v[i] -= 2.0 * eps;
            let lm = gp.mll(&GpHypers::from_vec(&v)).unwrap();
            v[i] += eps;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g).abs() < 1e-4 * (1.0 + g.abs()),
                "param {i}: fd {fd} vs analytic {g}"
            );
        }
    }

    #[test]
    fn predictive_variance_small_at_data_large_away() {
        let (xs, ys) = toy_1d(50, 4);
        let x0 = xs.get(0, 0);
        let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.5, 1.0, 1e-4));
        gp.refresh().unwrap();
        let xt = Matrix::from_vec(2, 1, vec![x0, 50.0]);
        let var = gp.predict_var(&xt);
        assert!(var[0] < 0.01, "at-data var {}", var[0]);
        assert!(var[1] > 0.9, "far-field var {}", var[1]);
    }

    #[test]
    fn mll_higher_for_true_noise_level() {
        let (xs, ys) = toy_1d(50, 5);
        let gp = ExactGp::new(xs, ys, GpHypers::default_init());
        let good = gp.mll(&GpHypers::new(0.7, 1.0, 0.01)).unwrap();
        let bad = gp.mll(&GpHypers::new(0.7, 1.0, 2.0)).unwrap();
        assert!(good > bad);
    }
}
