//! Gaussian-process models: exact baseline, SGPR baseline, the MVM family
//! (SKIP and KISS-GP), multi-task GPs, and the cluster multi-task model.

pub mod adam;
pub mod cluster;
pub mod exact;
pub mod hypers;
pub mod mtgp;
pub mod mvm;
pub mod sgpr;

pub use adam::Adam;
pub use cluster::{nearest_centroid, spatial_centroids, ClusterMtgp, ClusterMtgpConfig};
pub use exact::{ExactGp, ExactGradGp};
pub use hypers::GpHypers;
pub use mtgp::{Mtgp, MtgpConfig, MtgpData};
pub use mvm::{MvmGp, MvmGpConfig, MvmVariant, SolveSpace};
pub use sgpr::Sgpr;
