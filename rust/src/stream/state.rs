//! Incremental model state: ingest observations without retraining.
//!
//! SKI's fixed inducing grid makes online updates cheap (Gardner et al.
//! 2018's MVM-only framing, plus the fast-interpolation line of
//! Yadav–Sheldon–Musco): a new observation only changes the
//! interpolation matrix `W` by one sparse stencil row, so
//!
//! 1. **the operator extends in place** —
//!    [`KroneckerSkiOp::append_rows`] appends the stencil rows; grid,
//!    Toeplitz factors, and all existing rows are untouched;
//! 2. **the solve warm-starts** — `K̂α = y` is re-solved by PCG seeded
//!    with the previous α (padded with the standardized residual guess
//!    for the new rows), reusing the preconditioner cached at the last
//!    full refresh through [`PaddedPrecond`] while the hyperparameters
//!    are unchanged. With the solver policy's space
//!    ([`StreamConfig::policy`]) in grid mode the
//!    re-solve runs on the m-dimensional grid-space normal equations
//!    instead (`crate::solvers::gridspace`): `append_rows` folds the new
//!    stencil rows into the precomputed `WᵀW` band, `Wᵀy` is folded
//!    forward per accepted row, and the solve warm-starts from the
//!    previous grid iterate — whose length is the fixed grid size, so
//!    appends never invalidate it and per-iteration cost is independent
//!    of n;
//! 3. **the mean cache is patched, not rebuilt** — the grid-side scatter
//!    `Wᵀα` is updated with the α *delta* per stencil touch (entries with
//!    `|Δα| ≤ patch_eps·‖α‖_∞` are skipped), then one Kronecker–Toeplitz
//!    apply refreshes the mean cache;
//! 4. **the variance factor is rebuilt on drift** — the low-rank factor
//!    `R` tolerates a few extra observations (stale variance is an
//!    *over*-estimate of uncertainty, the conservative direction); once
//!    the tracked drift exceeds [`StreamConfig::var_drift_budget`]
//!    points it is rebuilt from the current operator;
//! 5. **a refresh policy escalates** — every N pending points, on a full
//!    observation ring, on an outlier (standardized residual beyond
//!    [`StreamConfig::error_z`]), or on a stalled incremental solve, a
//!    full [`IncrementalState::refresh`] rebuilds operator,
//!    preconditioner, α, and both caches from scratch and absorbs the
//!    pending log.
//!
//! Online updates require the dense-grid KISS path ([`MvmVariant::Kiss`]
//! with a single-term rectilinear grid): the SKIP merge tree bakes a
//! Lanczos decomposition of the *whole* data set into its operator, so
//! appending a row would invalidate it — streaming a SKIP model is a
//! typed [`Error::Stream`].
//!
//! # Derivative observations (D-SKI)
//!
//! A `(y, ∇y)` observation ([`IncrementalState::ingest_with_grad`])
//! appends **1 + d** stencil rows — the value row plus one derivative
//! stencil row per axis ([`KroneckerSkiOp::append_point`]) — and
//! (1 + d) solve targets, making `W_ext (⊗K) W_extᵀ` the structured
//! approximation of the derivative kernel `[[K, ∂K], [∂K, ∂²K]]`
//! (Eriksson et al. 2018). Everything above carries over row-for-row:
//! warm re-solves seed the derivative entries at zero, the grid-space
//! `Wᵀy` folds `∂y/∂x_a` through the matching derivative stencil, the
//! mean patch walks the interleaved row cursor, and the serving cache is
//! rebuilt with gradient-aware scatters
//! ([`crate::serve::cache::build_grad_cache`]) so
//! [`IncrementalState::predict_grad`] reads ∇μ from the same grid
//! buffer. Gradient observations are **single-task only** (the Hadamard
//! operator has no extended row form) and persist in snapshot format
//! v6+ pending logs.
//!
//! # Multi-task streaming
//!
//! A state built with [`IncrementalState::new_multitask`] carries a
//! coregionalization kernel (paper §6) and each row's task. Everything
//! above still holds — the data-side stencil `W` is task-blind — with
//! three substitutions: solves run against the Hadamard view
//! `σ_f²·(K_ski ∘ K_task) + σ_n²·I`
//! ([`crate::operators::TaskHadamardRef`], still MVM-only, so `--precond`,
//! warm starts, and `--precision mixed` apply unchanged); the mean patch
//! scatters each α delta into *every* task's masked scatter
//! `Wᵀ(c_t ∘ α)` in one stencil decode; and observations arrive as
//! `(task, x, y)` via [`IncrementalState::ingest_block_tasks`]. A
//! previously-unseen task id equal to the current task count **enrolls
//! online**: the task kernel grows a decoupled row
//! ([`crate::kernels::TaskKernel::enroll`]), the newcomer gets a zero
//! scatter and a placeholder cache (conservative prior variance until
//! the next rebuild), and the warm re-solve proceeds through the
//! existing [`PaddedPrecond`] exactly as a same-task append would.
//! Grid-space re-solves stay single-task — the Hadamard operator has no
//! grid-space normal form, so `--space grid` is a typed error and `Auto`
//! falls back to data space, metered under `solver.space.fallback`.

use super::log::{Observation, ObservationLog, PushOutcome};
use crate::gp::{GpHypers, MvmGp, MvmVariant, SolveSpace};
use crate::grid::{
    tensor_stencil, tensor_stencil_grad, tensor_strides, Grid1d, RectilinearGrid,
};
use crate::kernels::{ProductKernel, Stationary1d, TaskKernel};
use crate::linalg::{dot, Cholesky, Matrix, SymToeplitz};
use crate::operators::{AffineRef, KroneckerSkiOp, LinearOp, TaskHadamardRef};
use crate::serve::cache::{
    build_grad_cache, build_task_cache, inverse_root_exact, inverse_root_lanczos,
    mean_from_scatter, scatter_wt, PredictCache, TermCache, VarianceMode,
};
use crate::serve::snapshot::{
    ModelSnapshot, SnapshotVariant, TaskHead, SNAPSHOT_VERSION,
};
use crate::solvers::{
    block_cg_solve_with, build_preconditioner, cg_solve_with, grid_cg_solve_with_wty,
    CgConfig, GridSystem, IdentityPrecond, PaddedPrecond, Preconditioner, PrecondSpec,
    SolverPolicy,
};
use crate::{Error, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Streaming-ingestion policy knobs.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Escalate to a full refresh once this many observations are
    /// pending (0 disables the count trigger; the ring-capacity trigger
    /// still applies).
    pub refresh_every: usize,
    /// Rebuild the variance factor after this many points have been
    /// ingested since its last build (0 ⇒ rebuild on every ingest).
    pub var_drift_budget: usize,
    /// Escalate to a full refresh when an incoming observation's
    /// standardized residual `|y − μ(x)| / √(σ²(x) + σ_n²)` exceeds this
    /// (≤ 0 disables the trigger).
    pub error_z: f64,
    /// Pending-log ring capacity; a full ring forces a refresh.
    pub log_capacity: usize,
    /// How the variance factor is (re)built.
    pub variance: VarianceMode,
    /// Mean-patch threshold: skip scattering α deltas below
    /// `patch_eps · ‖α‖_∞` (0 ⇒ scatter every nonzero delta).
    pub patch_eps: f64,
    /// The solver policy for every solve this state issues — the same
    /// struct [`crate::gp::MvmGpConfig`] and
    /// [`crate::serve::SnapshotConfig`] embed. `policy.space` picks the
    /// space of the per-ingest α re-solves (grid space keeps the
    /// per-iteration cost independent of n — the natural fit for an
    /// ever-growing stream — with `WᵀW`/`Wᵀy` folded forward
    /// incrementally per accepted row; `Auto` picks grid space whenever
    /// the frozen axes admit it, see `docs/SOLVERS.md`);
    /// `policy.precision`/`policy.precond` are folded into the
    /// [`CgConfig`] at construction; `policy.warm_start` gates the
    /// previous-iterate seeds of the per-ingest re-solves.
    pub policy: SolverPolicy,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            refresh_every: 256,
            var_drift_budget: 32,
            error_z: 8.0,
            log_capacity: 1024,
            variance: VarianceMode::Lanczos(64),
            patch_eps: 1e-12,
            policy: SolverPolicy::default(),
        }
    }
}

/// Why an ingest escalated to a full refresh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefreshReason {
    /// [`StreamConfig::refresh_every`] pending observations reached.
    EveryN,
    /// The pending-observation ring filled.
    RingFull,
    /// An observation's standardized residual exceeded
    /// [`StreamConfig::error_z`].
    Outlier,
    /// The warm-started incremental solve did not converge.
    SolveStalled,
}

/// Per-row outcome of an ingest call, aligned with the input rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowOutcome {
    /// Ingested with this log sequence number.
    Accepted { seq: u64 },
    /// Bitwise duplicate of a pending observation — dropped.
    Duplicate,
}

/// What one [`IncrementalState::ingest_block`] call did.
#[derive(Clone, Debug)]
pub struct IngestReport {
    /// Per-input-row outcomes.
    pub outcomes: Vec<RowOutcome>,
    /// Rows actually ingested (non-duplicates).
    pub accepted: usize,
    /// Rows dropped as duplicates.
    pub duplicates: usize,
    /// Iterations of the warm-started α re-solve (0 when every row was
    /// a duplicate).
    pub solve_iters: usize,
    /// Iterations the warm start saved vs. the last cold (refresh-grade)
    /// solve of comparable size — the `stream.solve.iters_saved` metric.
    pub iters_saved: usize,
    /// α rows whose delta was scattered into the mean cache.
    pub rows_patched: usize,
    /// Whether this ingest rebuilt the variance factor under the drift
    /// budget (a full refresh — see [`refreshed`](Self::refreshed) —
    /// also rebuilds it, but is counted separately).
    pub var_rebuilt: bool,
    /// Whether (and why) this ingest escalated to a full refresh.
    pub refreshed: Option<RefreshReason>,
    /// Tasks enrolled online by this ingest (always 0 for single-task
    /// models and for blocks naming only existing tasks).
    pub enrolled: usize,
    /// Model size after the ingest.
    pub n: usize,
    /// Pending-log length after the ingest (0 right after a refresh).
    pub pending: usize,
}

/// A live model that ingests observations incrementally (see the module
/// docs for the update algebra).
pub struct IncrementalState {
    xs: Matrix,
    ys: Vec<f64>,
    /// Per-point gradient observations (D-SKI), aligned with `xs` rows.
    /// `Some` entries contribute d derivative stencil rows to the
    /// operator and d extra targets to every solve; an all-`None` vector
    /// keeps every code path bitwise-identical to the value-only model.
    /// Single-task only — the multi-task Hadamard view has no extended
    /// row form.
    grads: Vec<Option<Vec<f64>>>,
    hypers: GpHypers,
    /// The frozen inducing-grid axes — never refitted while streaming.
    axes: Vec<Grid1d>,
    /// SKI operator over the current data; grows by stencil rows.
    /// Behind an `Arc` so the grid-space solver ([`GridSystem`]) can
    /// share it per solve without copying the stencil — the clone is
    /// transient, so `Arc::get_mut` always succeeds at append time.
    op: Arc<KroneckerSkiOp>,
    /// Preconditioner built at the last refresh (covers the rows that
    /// existed then; grown systems see it through [`PaddedPrecond`]).
    pre: Box<dyn Preconditioner>,
    precond: PrecondSpec,
    cg: CgConfig,
    /// Current solve α = K̂⁻¹y.
    alpha: Vec<f64>,
    /// Grid-side scatter `Wᵀα` (single term), patched per ingest.
    wta: Vec<f64>,
    /// Grid-side projection `Wᵀy`, folded forward per accepted row while
    /// solving in grid space (empty in data-space mode) — the grid-space
    /// right-hand side never re-reads the n-vector y.
    wty: Vec<f64>,
    /// The last grid-space iterate q, the warm seed for the next ingest
    /// re-solve. Its length is M (grid size), which never changes while
    /// streaming — appends resize the *data* side only, so the seed
    /// survives every `append_rows` by construction.
    grid_q: Option<Vec<f64>>,
    /// Resolved at each refresh from [`StreamConfig::space`] (the axes
    /// are frozen, so feasibility never changes between refreshes).
    grid_active: bool,
    /// Per-axis Toeplitz grid-kernel factors — invariant while streaming
    /// (axes and hyperparameters are frozen), built once so the per-
    /// ingest mean patch pays only the Kronecker apply.
    factors: Vec<SymToeplitz>,
    /// Live predictive cache (mean patched per ingest; variance factor
    /// rebuilt on drift). For multi-task states this is **task 0's
    /// masked** cache — `wta` likewise holds task 0's masked scatter —
    /// so the single-task layout doubles as the task-0 head.
    cache: PredictCache,
    /// Multi-task extension: the task kernel, per-row assignments, and
    /// the scatters/caches of tasks `1..s`. `None` for single-task
    /// states, whose code paths are bitwise-unchanged by its existence.
    mt: Option<MtState>,
    /// Model size when the variance factor was last built.
    var_built_at: usize,
    /// Iterations of the last cold (refresh-grade) solve — the baseline
    /// the warm-start savings metric is measured against.
    last_cold_iters: usize,
    log: ObservationLog,
    cfg: StreamConfig,
    /// Cumulative ingest counters (mirrored into serving metrics by the
    /// engine layer).
    pub stats: StreamStats,
}

/// The multi-task extension of a live state (tasks `1..s`; task 0 rides
/// the base `wta`/`cache` fields, which hold its *masked* versions
/// whenever this is present — the same split the snapshot format uses).
struct MtState {
    /// Coregionalization kernel `B Bᵀ + D`; grows by one decoupled row
    /// per online enrollment.
    kernel: TaskKernel,
    /// Task of every training row (length n).
    task_of: Vec<usize>,
    /// Masked grid scatters `Wᵀ(c_t ∘ α)` for tasks `1..s`, patched per
    /// ingest alongside the base scatter.
    wtas: Vec<Vec<f64>>,
    /// Per-task serving caches for tasks `1..s`.
    caches: Vec<PredictCache>,
}

/// Cumulative streaming counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    pub points: u64,
    pub duplicates: u64,
    pub mean_patches: u64,
    pub var_rebuilds: u64,
    pub refreshes: u64,
    pub outlier_refreshes: u64,
    /// Tasks enrolled online (multi-task states only).
    pub enrollments: u64,
    /// Variance rebuilds / policy refreshes that failed *after* the
    /// ingest itself succeeded (the model keeps serving; see
    /// [`IncrementalState::ingest_block`]).
    pub maintenance_failures: u64,
}

impl IncrementalState {
    /// Build a live state from raw parts. `axes` are the frozen inducing
    /// grid; performs one full [`refresh`](Self::refresh) to initialize
    /// α, the preconditioner, and both caches.
    pub fn new(
        xs: Matrix,
        ys: Vec<f64>,
        hypers: GpHypers,
        axes: Vec<Grid1d>,
        cg: CgConfig,
        cfg: StreamConfig,
    ) -> Result<Self> {
        let mut state = Self::build(xs, ys, hypers, axes, cg, cfg)?;
        state.refresh()?;
        Ok(state)
    }

    /// Build a live **multi-task** state: `tasks` pairs the
    /// coregionalization kernel with each training row's task id. Same
    /// contract as [`new`](Self::new) otherwise — one full refresh
    /// initializes α (solved against the Hadamard view), the
    /// preconditioner, and every per-task cache.
    pub fn new_multitask(
        xs: Matrix,
        ys: Vec<f64>,
        tasks: (TaskKernel, Vec<usize>),
        hypers: GpHypers,
        axes: Vec<Grid1d>,
        cg: CgConfig,
        cfg: StreamConfig,
    ) -> Result<Self> {
        let (kernel, task_of) = tasks;
        if task_of.len() != xs.rows {
            return Err(Error::DimMismatch {
                context: "stream task assignments",
                expected: xs.rows,
                got: task_of.len(),
            });
        }
        let s = kernel.num_tasks();
        if s == 0 {
            return Err(Error::Stream(
                "multi-task model needs at least one task".into(),
            ));
        }
        if let Some(&t) = task_of.iter().find(|&&t| t >= s) {
            return Err(Error::Stream(format!(
                "task assignment {t} out of range (task kernel has {s} tasks)"
            )));
        }
        let mut state = Self::build(xs, ys, hypers, axes, cg, cfg)?;
        state.mt = Some(MtState {
            kernel,
            task_of,
            wtas: Vec::new(),
            caches: Vec::new(),
        });
        state.refresh()?;
        Ok(state)
    }

    /// Shared constructor body: validate, freeze the axes, and assemble
    /// the (not-yet-refreshed) state.
    fn build(
        xs: Matrix,
        ys: Vec<f64>,
        hypers: GpHypers,
        axes: Vec<Grid1d>,
        cg: CgConfig,
        cfg: StreamConfig,
    ) -> Result<Self> {
        if xs.rows != ys.len() {
            return Err(Error::DimMismatch {
                context: "stream training targets",
                expected: xs.rows,
                got: ys.len(),
            });
        }
        if axes.len() != xs.cols {
            return Err(Error::DimMismatch {
                context: "stream grid axes",
                expected: xs.cols,
                got: axes.len(),
            });
        }
        // Fold the policy's precision/preconditioner switches into the
        // CG config every solve site (ingest re-solve, refresh, variance
        // block-solve) consumes. The policy only ever adds — a caller
        // that set `cg.precision`/`cg.precond` directly keeps their
        // choice under a default policy.
        let mut cg = cg;
        cfg.policy.fold_into(&mut cg);
        let kern = ProductKernel::rbf(xs.cols, hypers.ell(), 1.0);
        let op = Arc::new(KroneckerSkiOp::with_grids(&xs, &kern, axes.clone()));
        let n = xs.rows;
        let total: usize = axes.iter().map(|g| g.m).product();
        let kern1 = Stationary1d::rbf(hypers.ell());
        let factors: Vec<SymToeplitz> = axes
            .iter()
            .map(|g| SymToeplitz::new(kern1.toeplitz_column(g.m, g.h)))
            .collect();
        // Zeroed mean-only cache of the right shape; refresh() below
        // replaces it with the real one.
        let empty = PredictCache::from_parts(
            crate::grid::GridSpec::Rectilinear(axes.iter().map(|g| g.m).collect()),
            vec![TermCache::new(
                1.0,
                axes.clone(),
                vec![0.0; total],
                Matrix::zeros(total, 0),
            )?],
            hypers.sf2(),
            hypers.sn2(),
        )?;
        Ok(IncrementalState {
            xs,
            ys,
            grads: vec![None; n],
            hypers,
            axes,
            op,
            pre: Box::new(IdentityPrecond::new(n)),
            precond: cg.precond,
            cg,
            alpha: vec![0.0; n],
            wta: vec![0.0; total],
            wty: Vec::new(),
            grid_q: None,
            grid_active: false,
            factors,
            cache: empty,
            mt: None,
            var_built_at: 0,
            last_cold_iters: 0,
            log: ObservationLog::new(cfg.log_capacity),
            cfg,
            stats: StreamStats::default(),
        })
    }

    /// True iff any training point carries a gradient observation — the
    /// switch between the value-only paths (bitwise-legacy) and the
    /// extended-row D-SKI paths.
    fn has_any_grad(&self) -> bool {
        self.grads.iter().any(Option::is_some)
    }

    /// Per-point gradient-presence mask, the row layout key shared with
    /// [`crate::kernels::deriv_layout`].
    fn grad_mask(&self) -> Vec<bool> {
        self.grads.iter().map(Option::is_some).collect()
    }

    /// Extended-system row count: one row per point plus d per gradient
    /// observation — the length of α and of every solve's target vector.
    fn num_rows(&self) -> usize {
        let d = self.xs.cols;
        self.xs.rows + d * self.grads.iter().filter(|g| g.is_some()).count()
    }

    /// The solve targets: `ys` verbatim for value-only states (borrowed,
    /// no copy on the hot path), or the interleaved `(y, ∇y)` vector
    /// when any point carries a gradient.
    fn targets(&self) -> Cow<'_, [f64]> {
        if !self.has_any_grad() {
            return Cow::Borrowed(&self.ys[..]);
        }
        let mut t = Vec::with_capacity(self.num_rows());
        for (y, g) in self.ys.iter().zip(&self.grads) {
            t.push(*y);
            if let Some(g) = g {
                t.extend_from_slice(g);
            }
        }
        Cow::Owned(t)
    }

    /// Adopt a trained [`MvmGp`] for streaming. Requires the KISS
    /// (dense-grid) variant on a single-term grid; the grid axes are
    /// fitted once here and frozen. A model trained with gradient
    /// observations ([`MvmGp::new_with_grads`]) carries them into the
    /// live state — its extended operator keeps growing by
    /// value-or-gradient stencil rows per ingest.
    pub fn from_mvm(gp: &MvmGp, cfg: StreamConfig) -> Result<Self> {
        if gp.cfg.variant != MvmVariant::Kiss {
            return Err(Error::Stream(
                "online updates require the KISS (grid) variant — SKIP \
                 models remain unsupported (single- and multi-task alike): \
                 the SKIP merge tree bakes a whole-data Lanczos \
                 decomposition into its operator and cannot extend by one \
                 row"
                    .into(),
            ));
        }
        let axes = gp.fitted_grid_axes().map_err(|e| {
            Error::Stream(format!(
                "online updates require a single-term dense grid \
                 (Uniform/Rectilinear spec) — sparse-grid multi-term \
                 models remain unsupported (single- and multi-task \
                 alike): {e}"
            ))
        })?;
        let mut cg = gp.cfg.cg;
        cg.max_iters = cg.max_iters.max(200);
        let mut state =
            Self::build(gp.xs.clone(), gp.ys.clone(), gp.hypers, axes, cg, cfg)?;
        if let Some(g) = gp.grads() {
            state.grads = (0..g.rows).map(|i| Some(g.row(i).to_vec())).collect();
        }
        state.refresh()?;
        Ok(state)
    }

    /// The noise-shifted covariance view `σ_f²·K_ski + σ_n²·I` over the
    /// in-place-extended SKI operator — [`AffineRef`] shares `AffineOp`'s
    /// arithmetic, so incremental solves agree with the batch path's
    /// operator bitwise. Single-task only; multi-task solves go through
    /// [`with_view`](Self::with_view).
    fn view(&self) -> AffineRef<'_> {
        AffineRef {
            inner: self.op.as_ref(),
            scale: self.hypers.sf2(),
            shift: self.hypers.sn2(),
        }
    }

    /// Run `f` against the covariance view of the current model:
    /// `σ_f²·K_ski + σ_n²·I` single-task, or the Hadamard composition
    /// `σ_f²·(K_ski ∘ K_task) + σ_n²·I` multi-task (the SKI operator is
    /// built with unit outputscale, so one `σ_f²` scaling serves both).
    /// The per-call [`TaskHadamardRef`] borrows the shared stencil — no
    /// copy — and lives exactly as long as the solve using it.
    fn with_view<R>(&self, f: impl FnOnce(&dyn LinearOp) -> R) -> R {
        match &self.mt {
            None => f(&self.view()),
            Some(mt) => {
                let had =
                    TaskHadamardRef::new(self.op.as_ref(), &mt.task_of, &mt.kernel);
                f(&AffineRef {
                    inner: &had,
                    scale: self.hypers.sf2(),
                    shift: self.hypers.sn2(),
                })
            }
        }
    }

    /// Resolve [`StreamConfig::space`] against the frozen grid: whether
    /// the per-ingest re-solves run in grid space. Explicit `Grid`
    /// propagates the typed refusal (over-budget `WᵀW` band, degenerate
    /// axes); `Auto` falls back to data space on it. The call eagerly
    /// builds the `WᵀW` band when feasible, so later `append_rows` calls
    /// fold into it incrementally.
    fn resolve_space(&self) -> Result<bool> {
        if self.mt.is_some() {
            return match self.cfg.policy.space {
                SolveSpace::Grid => Err(Error::Stream(
                    "grid-space re-solves are single-task only — the \
                     multi-task Hadamard operator (K_ski ∘ K_task) has no \
                     grid-space normal form; use --space data (or auto, \
                     which falls back to data space)"
                        .into(),
                )),
                SolveSpace::Data => Ok(false),
                SolveSpace::Auto => {
                    crate::coordinator::metrics::global()
                        .incr("solver.space.fallback", 1);
                    Ok(false)
                }
            };
        }
        match self.cfg.policy.space {
            SolveSpace::Data => Ok(false),
            SolveSpace::Grid => {
                self.op.grid_space_op()?;
                Ok(true)
            }
            SolveSpace::Auto => match self.op.grid_space_op() {
                Ok(_) => Ok(true),
                Err(Error::Grid(_)) => {
                    crate::coordinator::metrics::global()
                        .incr("solver.space.fallback", 1);
                    Ok(false)
                }
                Err(e) => Err(e),
            },
        }
    }

    /// The grid-space normal-equations system over the shared operator.
    /// Transient per solve: the `Arc` clone inside is dropped with the
    /// returned system, keeping `Arc::get_mut` available at append time.
    fn grid_system(&self) -> Result<GridSystem> {
        GridSystem::new(
            vec![(1.0, self.op.clone())],
            self.hypers.sf2(),
            self.hypers.sn2(),
        )
    }

    /// The preconditioner for a solve on the current n-row system:
    /// identity when unpreconditioned, otherwise the refresh-time
    /// preconditioner padded out to any rows appended since (a pad of
    /// zero rows is an exact pass-through) — one selection shared by the
    /// ingest and variance solves so they can never diverge.
    fn solve_precond(&self) -> Box<dyn Preconditioner + '_> {
        if matches!(self.precond, PrecondSpec::None) {
            Box::new(IdentityPrecond::new(self.num_rows()))
        } else {
            Box::new(PaddedPrecond::new(
                self.pre.as_ref(),
                self.num_rows(),
                self.hypers.sf2() + self.hypers.sn2(),
            ))
        }
    }

    /// Full refresh: rebuild operator, preconditioner, α (cold solve —
    /// this is the baseline incremental ingests are measured against),
    /// the grid scatter, and both caches; absorb the pending log.
    pub fn refresh(&mut self) -> Result<()> {
        let kern = ProductKernel::rbf(self.xs.cols, self.hypers.ell(), 1.0);
        self.op = if self.has_any_grad() {
            // Mixed value/gradient rows: grow an empty operator point by
            // point so each gradient-carrying observation contributes its
            // d derivative stencil rows in the canonical interleaved
            // order ([`KroneckerSkiOp::append_point`]).
            let mut op = KroneckerSkiOp::with_grids(
                &Matrix::zeros(0, self.xs.cols),
                &kern,
                self.axes.clone(),
            );
            for i in 0..self.xs.rows {
                op.append_point(self.xs.row(i), self.grads[i].is_some());
            }
            Arc::new(op)
        } else {
            Arc::new(KroneckerSkiOp::with_grids(&self.xs, &kern, self.axes.clone()))
        };
        let targets = self.targets().into_owned();
        // The data-space preconditioner is kept in both modes: variance
        // solves (`predict_var`, the Lanczos factor) stay in data space.
        // Built against the full (multi-task-aware) view.
        self.pre = self.with_view(|view| {
            build_preconditioner(view, Some(self.hypers.sn2()), self.precond)
        });
        self.grid_active = self.resolve_space()?;
        let mut grid_result: Option<(usize, bool, f64)> = None;
        if self.grid_active {
            // Cold grid-space solve; Wᵀy is rebuilt from scratch here and
            // only folded forward incrementally between refreshes. With
            // gradient rows the extended Wᵀ folds the interleaved
            // (y, ∇y) targets through value and derivative stencils
            // alike.
            self.wty = self.op.wt_matvec(&targets);
            let sys = self.grid_system()?;
            let sol = grid_cg_solve_with_wty(&sys, &targets, &self.wty, None, self.cg);
            drop(sys);
            if sol.converged || self.cfg.policy.space == SolveSpace::Grid {
                self.alpha = sol.alpha;
                self.grid_q = Some(sol.v);
                grid_result = Some((sol.iters, sol.converged, sol.rel_residual));
            } else {
                // Auto commits to grid space only when the cold solve
                // demonstrably converges in the configured budget —
                // otherwise this state demotes to data space for good
                // (the frozen axes make the retry deterministic).
                crate::coordinator::metrics::global()
                    .incr("solver.space.fallback", 1);
                self.grid_active = false;
            }
        }
        let (iters, converged, residual) = match grid_result {
            Some(r) => r,
            None => {
                crate::coordinator::metrics::global().incr("solver.space.data", 1);
                let sol = self.with_view(|view| {
                    cg_solve_with(view, &targets, self.pre.as_ref(), None, self.cg)
                });
                self.alpha = sol.x;
                self.wty = Vec::new();
                self.grid_q = None;
                (sol.iters, sol.converged, sol.rel_residual)
            }
        };
        if !converged {
            return Err(Error::CgDidNotConverge { iters, residual });
        }
        self.last_cold_iters = iters;
        self.rebuild_scatter();
        self.rebuild_cache()?;
        self.var_built_at = self.xs.rows;
        self.log.absorb();
        self.stats.refreshes += 1;
        Ok(())
    }

    /// Ingest one observation. See [`ingest_block`](Self::ingest_block).
    pub fn ingest(&mut self, x: &[f64], y: f64) -> Result<IngestReport> {
        if x.len() != self.xs.cols {
            return Err(Error::DimMismatch {
                context: "ingested observation dimensionality",
                expected: self.xs.cols,
                got: x.len(),
            });
        }
        let xs = Matrix::from_vec(1, self.xs.cols, x.to_vec());
        self.ingest_block(&xs, &[y])
    }

    /// Ingest a block of observations: extend `W`/`y` in place, re-solve
    /// α seeded from the previous solution, patch the mean cache, and
    /// apply the variance-drift and refresh policies. Duplicates of
    /// pending observations are dropped row-wise. Single-task models
    /// only — a multi-task model's observations must name their task
    /// ([`ingest_block_tasks`](Self::ingest_block_tasks)).
    pub fn ingest_block(&mut self, xs_new: &Matrix, ys_new: &[f64]) -> Result<IngestReport> {
        if self.mt.is_some() {
            return Err(Error::Stream(
                "this model is multi-task — observations must name a task \
                 (observe <task> x… y)"
                    .into(),
            ));
        }
        self.ingest_inner(xs_new, ys_new, None, None)
    }

    /// Ingest one `(y, ∇y)` observation — see
    /// [`ingest_block_grads`](Self::ingest_block_grads).
    pub fn ingest_with_grad(
        &mut self,
        x: &[f64],
        y: f64,
        grad: &[f64],
    ) -> Result<IngestReport> {
        let d = self.xs.cols;
        if x.len() != d {
            return Err(Error::DimMismatch {
                context: "ingested observation dimensionality",
                expected: d,
                got: x.len(),
            });
        }
        let xs = Matrix::from_vec(1, d, x.to_vec());
        let grads = Matrix::from_vec(1, d, grad.to_vec());
        self.ingest_block_grads(&xs, &[y], &grads)
    }

    /// Ingest a block of `(y, ∇y)` observations (D-SKI): each accepted
    /// row appends its value stencil row **plus d derivative stencil
    /// rows** to the operator and (1+d) targets to the solve, then the
    /// warm re-solve / mean patch / drift policies run exactly as in
    /// [`ingest_block`](Self::ingest_block). Single-task only — the
    /// multi-task Hadamard operator has no extended row form.
    pub fn ingest_block_grads(
        &mut self,
        xs_new: &Matrix,
        ys_new: &[f64],
        grads_new: &Matrix,
    ) -> Result<IngestReport> {
        if self.mt.is_some() {
            return Err(Error::Stream(
                "gradient observations are single-task only — the \
                 multi-task Hadamard operator (K_ski ∘ K_task) has no \
                 extended derivative-row form"
                    .into(),
            ));
        }
        if grads_new.rows != xs_new.rows || grads_new.cols != xs_new.cols {
            return Err(Error::DimMismatch {
                context: "ingested observation gradients",
                expected: xs_new.rows * xs_new.cols,
                got: grads_new.rows * grads_new.cols,
            });
        }
        self.ingest_inner(xs_new, ys_new, None, Some(grads_new))
    }

    /// Ingest a block of `(task, x, y)` observations into a multi-task
    /// model. Same contract as [`ingest_block`](Self::ingest_block),
    /// plus **online task enrollment**: a task id equal to the current
    /// task count enrolls a new task mid-stream (ids beyond that are a
    /// typed error — rows are scanned in order, so one block may enroll
    /// several consecutive tasks). Dedup keys on the full `(task, x, y)`
    /// triple.
    pub fn ingest_block_tasks(
        &mut self,
        xs_new: &Matrix,
        ys_new: &[f64],
        tasks: &[usize],
    ) -> Result<IngestReport> {
        if self.mt.is_none() {
            return Err(Error::Stream(
                "this model is single-task — observations cannot name a \
                 task (observe x… y); build it with new_multitask to \
                 serve tasks"
                    .into(),
            ));
        }
        if tasks.len() != xs_new.rows {
            return Err(Error::DimMismatch {
                context: "ingested observation tasks",
                expected: xs_new.rows,
                got: tasks.len(),
            });
        }
        self.ingest_inner(xs_new, ys_new, Some(tasks), None)
    }

    /// Shared ingest body; `tasks` is `Some` exactly when `self.mt` is,
    /// and `grads_new` (one ∇y row per input row) only ever arrives on
    /// single-task states ([`ingest_block_grads`](Self::ingest_block_grads)
    /// rejects the combination).
    fn ingest_inner(
        &mut self,
        xs_new: &Matrix,
        ys_new: &[f64],
        tasks: Option<&[usize]>,
        grads_new: Option<&Matrix>,
    ) -> Result<IngestReport> {
        let d = self.xs.cols;
        if xs_new.cols != d {
            return Err(Error::DimMismatch {
                context: "ingested observation dimensionality",
                expected: d,
                got: xs_new.cols,
            });
        }
        if xs_new.rows != ys_new.len() {
            return Err(Error::DimMismatch {
                context: "ingested observation targets",
                expected: xs_new.rows,
                got: ys_new.len(),
            });
        }
        let grad_at =
            |i: usize| -> Option<&[f64]> { grads_new.map(|g| g.row(i)) };
        for i in 0..xs_new.rows {
            if !ys_new[i].is_finite() || xs_new.row(i).iter().any(|v| !v.is_finite()) {
                return Err(Error::Stream(format!(
                    "non-finite observation at row {i}"
                )));
            }
            if grad_at(i).is_some_and(|g| g.iter().any(|v| !v.is_finite())) {
                return Err(Error::Stream(format!(
                    "non-finite gradient observation at row {i}"
                )));
            }
        }

        // Online-enrollment pre-scan: a previously-unseen task id is
        // legal only as the *next* one. Rows are scanned in order, so a
        // block may enroll several consecutive tasks, each introduced by
        // its first row; anything beyond the running count is a typed
        // error before the block touches any state.
        let task_at = |i: usize| tasks.map_or(0, |t| t[i]);
        if let Some(ts) = tasks {
            let mut s_virtual = self.num_tasks();
            for (i, &t) in ts.iter().enumerate() {
                if t > s_virtual {
                    return Err(Error::Stream(format!(
                        "task {t} out of range at row {i}: the model has \
                         {s_virtual} tasks (task {s_virtual} would enroll \
                         a new one)"
                    )));
                }
                if t == s_virtual {
                    s_virtual += 1;
                }
            }
        }

        // Row-wise dedup: against the pending log (client retries) AND
        // against earlier rows of this very block — two clients retrying
        // the same observation can land in one coalesced batch. The key
        // is the full (task, x, y, ∇y) tuple; within one block the rows
        // share a single gradient matrix (all-Some or all-None), so the
        // value comparison suffices there once the gradients match.
        let bits_eq = |i: usize, j: usize| {
            task_at(i) == task_at(j)
                && ys_new[i].to_bits() == ys_new[j].to_bits()
                && xs_new
                    .row(i)
                    .iter()
                    .zip(xs_new.row(j))
                    .all(|(a, b)| a.to_bits() == b.to_bits())
                && match (grad_at(i), grad_at(j)) {
                    (None, None) => true,
                    (Some(a), Some(b)) => {
                        a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
                    }
                    _ => unreachable!("one gradient matrix per block"),
                }
        };
        let mut outcomes: Vec<RowOutcome> = Vec::with_capacity(xs_new.rows);
        let mut fresh_rows: Vec<usize> = Vec::with_capacity(xs_new.rows);
        for i in 0..xs_new.rows {
            let duplicate = self.log.contains_with_grad(
                task_at(i),
                xs_new.row(i),
                ys_new[i],
                grad_at(i),
            ) || fresh_rows.iter().any(|&j| bits_eq(i, j));
            if duplicate {
                outcomes.push(RowOutcome::Duplicate);
            } else {
                // Seq assigned below, after the solve succeeds.
                outcomes.push(RowOutcome::Accepted { seq: 0 });
                fresh_rows.push(i);
            }
        }
        let duplicates = xs_new.rows - fresh_rows.len();
        self.stats.duplicates += duplicates as u64;
        if fresh_rows.is_empty() {
            return Ok(IngestReport {
                outcomes,
                accepted: 0,
                duplicates,
                solve_iters: 0,
                iters_saved: 0,
                rows_patched: 0,
                var_rebuilt: false,
                refreshed: None,
                enrolled: 0,
                n: self.xs.rows,
                pending: self.log.len(),
            });
        }

        // Enroll the new tasks named by accepted rows, *before* the
        // guesses below so every task has a cache to predict from: the
        // kernel grows a decoupled row, the newcomer gets a zero scatter
        // and a placeholder cache — zero mean, zero variance factor, so
        // it serves the conservative prior variance σ_f²·k_task(t,t)
        // until the next rebuild. The post-solve mean patch then fills
        // the scatter from the task's own rows (existing rows contribute
        // nothing: their cross-covariance to the decoupled task is 0).
        let mut enrolled = 0usize;
        if tasks.is_some() {
            let total: usize = self.axes.iter().map(|g| g.m).product();
            let r = self.cache.var_rank();
            let spec = self.cache.spec.clone();
            let sf2 = self.hypers.sf2();
            let sn2 = self.hypers.sn2();
            let mt = self.mt.as_mut().expect("task ingests are multi-task");
            for &i in &fresh_rows {
                let t = task_at(i);
                if t == mt.kernel.num_tasks() {
                    let id = mt.kernel.enroll();
                    let prior = sf2 * mt.kernel.eval(id, id);
                    let term = TermCache::new(
                        1.0,
                        self.axes.clone(),
                        vec![0.0; total],
                        Matrix::zeros(total, r),
                    )?;
                    mt.caches.push(PredictCache::from_parts(
                        spec.clone(),
                        vec![term],
                        prior,
                        sn2,
                    )?);
                    mt.wtas.push(vec![0.0; total]);
                    enrolled += 1;
                }
            }
            self.stats.enrollments += enrolled as u64;
        }

        // Pre-ingest predictive view of the fresh points: the warm-seed
        // guess for their α entries and the outlier z-scores, each read
        // from the observation's own task cache with its task's prior
        // variance in the denominator. A gradient-carrying row seeds its
        // value α entry the same way and its d derivative entries at 0
        // (no cheap standardized-residual analogue for derivative rows).
        let denom0 = self.hypers.sf2() + self.hypers.sn2();
        let mut guesses = Vec::with_capacity(fresh_rows.len());
        let mut max_z = 0.0f64;
        for &i in &fresh_rows {
            let x = xs_new.row(i);
            let t = task_at(i);
            let cache = self
                .task_cache(t)
                .expect("enrollment above covers every accepted task");
            let resid = ys_new[i] - cache.predict_mean_one(x);
            let var = if cache.has_variance() {
                cache.predict_var_one(x)
            } else {
                cache.prior_var
            };
            max_z = max_z.max(resid.abs() / (var + self.hypers.sn2()).sqrt());
            let denom = match &self.mt {
                None => denom0,
                Some(mt) => {
                    self.hypers.sf2() * mt.kernel.eval(t, t) + self.hypers.sn2()
                }
            };
            guesses.push(resid / denom);
            if grad_at(i).is_some() {
                guesses.extend(std::iter::repeat(0.0).take(d));
            }
        }

        // Extend the data, W (and, in grid mode, WᵀW — `append_rows`
        // folds the new stencil rows into the built band) in place.
        let n_old = self.xs.rows;
        let block = Matrix::from_fn(fresh_rows.len(), d, |r, c| {
            xs_new.get(fresh_rows[r], c)
        });
        self.xs.data.extend_from_slice(&block.data);
        self.xs.rows += block.rows;
        for &i in &fresh_rows {
            self.ys.push(ys_new[i]);
            self.grads.push(grad_at(i).map(<[f64]>::to_vec));
        }
        if let Some(ts) = tasks {
            let mt = self.mt.as_mut().expect("task ingests are multi-task");
            for &i in &fresh_rows {
                mt.task_of.push(ts[i]);
            }
        }
        {
            let op = Arc::get_mut(&mut self.op)
                .expect("grid systems are transient — no clone outlives its solve");
            if self.grads.iter().any(Option::is_some) {
                // Extended-row operator: each accepted point appends its
                // value row plus (when it carries a gradient) d
                // derivative stencil rows, keeping the interleaved D-SKI
                // layout — and, in grid mode, folding every new row into
                // the built WᵀW band.
                for (r, &i) in fresh_rows.iter().enumerate() {
                    op.append_point(block.row(r), grad_at(i).is_some());
                }
            } else {
                op.append_rows(&block);
            }
        }
        let n = self.xs.rows;

        let alpha_old = std::mem::take(&mut self.alpha);

        let (solve_iters, stalled) = if self.grid_active {
            // Grid space: fold the new targets into Wᵀy through the same
            // stencil W just grew by, then re-solve the m-dimensional
            // system warm-started from the previous grid iterate q —
            // whose length is the (fixed) grid size, so appends never
            // invalidate it. Per-iteration cost stays independent of n.
            let dims: Vec<usize> = self.axes.iter().map(|g| g.m).collect();
            let strides = tensor_strides(&dims);
            let mut wty = std::mem::take(&mut self.wty);
            for (r, &i) in fresh_rows.iter().enumerate() {
                let y = ys_new[i];
                tensor_stencil(block.row(r), &self.axes, &strides, |g, w| {
                    wty[g] += w * y;
                });
                // Gradient rows fold their ∂y/∂x_axis target through the
                // matching derivative stencil — the W_extᵀ(y, ∇y)
                // contribution of the new rows, never re-reading the
                // n-vector.
                if let Some(gv) = grad_at(i) {
                    for (axis, &g_a) in gv.iter().enumerate() {
                        tensor_stencil_grad(
                            block.row(r),
                            axis,
                            &self.axes,
                            &strides,
                            |g, w| {
                                wty[g] += w * g_a;
                            },
                        );
                    }
                }
            }
            self.wty = wty;
            let targets = self.targets().into_owned();
            let sys = self.grid_system()?;
            let q0 = if self.cfg.policy.warm_start {
                self.grid_q.as_deref()
            } else {
                None
            };
            let sol = grid_cg_solve_with_wty(&sys, &targets, &self.wty, q0, self.cg);
            drop(sys);
            self.alpha = sol.alpha;
            self.grid_q = Some(sol.v);
            (sol.iters, !sol.converged)
        } else {
            // Data space: warm-started PCG seeded with the previous α
            // padded by the standardized-residual guesses (zeros for
            // derivative rows), reusing the refresh-time preconditioner
            // padded out to the grown system (exact diagonal on the
            // tail).
            let mut seed = alpha_old.clone();
            seed.extend_from_slice(&guesses);
            let x0 = if self.cfg.policy.warm_start {
                Some(seed.as_slice())
            } else {
                None
            };
            crate::coordinator::metrics::global().incr("solver.space.data", 1);
            let targets = self.targets().into_owned();
            let pre = self.solve_precond();
            let sol = self.with_view(|view| {
                cg_solve_with(view, &targets, pre.as_ref(), x0, self.cg)
            });
            // End the Box's borrow of self.pre before the &mut self calls
            // below (Box drop glue keeps it live otherwise).
            drop(pre);
            self.alpha = sol.x;
            (sol.iters, !sol.converged)
        };
        let iters_saved = self.last_cold_iters.saturating_sub(solve_iters);

        // Patch the mean cache: scatter the α delta per stencil touch,
        // then one grid apply.
        let rows_patched = self.patch_mean(&alpha_old, n_old);
        self.stats.mean_patches += 1;
        self.stats.points += fresh_rows.len() as u64;

        // Log the accepted rows now that they are part of the model.
        let mut fresh_iter = fresh_rows.iter();
        for o in outcomes.iter_mut() {
            if let RowOutcome::Accepted { seq } = o {
                let i = *fresh_iter.next().expect("fresh row for outcome");
                match self.log.push_with_grad(
                    task_at(i),
                    xs_new.row(i),
                    ys_new[i],
                    grad_at(i),
                ) {
                    PushOutcome::Appended(s) => *seq = s,
                    PushOutcome::Duplicate => unreachable!("deduped above"),
                }
            }
        }

        // Refresh policy first: every-N / ring-full / outlier / stalled
        // solve. A pending refresh rebuilds the whole cache anyway, so
        // the drift-budget variance rebuild below is skipped then (a
        // refresh-triggering ingest must not pay the rebuild twice).
        let reason = if stalled {
            Some(RefreshReason::SolveStalled)
        } else if self.cfg.refresh_every > 0 && self.log.len() >= self.cfg.refresh_every {
            Some(RefreshReason::EveryN)
        } else if self.log.is_full() {
            Some(RefreshReason::RingFull)
        } else if self.cfg.error_z > 0.0 && max_z > self.cfg.error_z {
            Some(RefreshReason::Outlier)
        } else {
            None
        };

        // Maintenance (variance rebuild, policy refresh) normally must
        // not fail the ingest: the observations are already part of the
        // model and logged, so an error ack would lie to the client —
        // a failed rebuild keeps serving the (conservatively stale)
        // variance, a failed refresh leaves the log pending for the
        // next trigger, and `maintenance_failures` ticks. The one
        // exception is a stalled solve whose escalated refresh also
        // fails: then α itself never converged and the mean would be
        // *wrong*, not stale — that error must surface (the points are
        // logged, so a bitwise retry is deduped, never double-counted).
        let mut var_rebuilt = false;
        if reason.is_none()
            && self.cache.has_variance()
            && n - self.var_built_at > self.cfg.var_drift_budget
        {
            match self.rebuild_cache() {
                Ok(()) => {
                    self.var_built_at = n;
                    self.stats.var_rebuilds += 1;
                    var_rebuilt = true;
                }
                Err(_) => self.stats.maintenance_failures += 1,
            }
        }
        let mut refreshed = None;
        if let Some(r) = reason {
            if r == RefreshReason::Outlier {
                self.stats.outlier_refreshes += 1;
            }
            match self.refresh() {
                Ok(()) => refreshed = Some(r),
                Err(e) => {
                    self.stats.maintenance_failures += 1;
                    if r == RefreshReason::SolveStalled {
                        return Err(e);
                    }
                }
            }
        }

        Ok(IngestReport {
            outcomes,
            accepted: fresh_rows.len(),
            duplicates,
            solve_iters,
            iters_saved,
            rows_patched,
            var_rebuilt,
            refreshed,
            enrolled,
            n,
            pending: self.log.len(),
        })
    }

    /// Replay observations (e.g. a reloaded snapshot's pending section)
    /// into this model, in chronological order. Multi-task models route
    /// each observation to its recorded task (re-enrolling any task that
    /// was first seen mid-stream); single-task models reject entries
    /// naming a nonzero task. Gradient-carrying entries (snapshot v6+)
    /// replay through [`ingest_block_grads`](Self::ingest_block_grads):
    /// consecutive same-kind entries are chunked into one block each, so
    /// chronological order is preserved while a homogeneous pending log
    /// still replays as a single solve.
    pub fn ingest_observations(&mut self, obs: &[Observation]) -> Result<IngestReport> {
        let d = self.xs.cols;
        for o in obs {
            if o.x.len() != d {
                return Err(Error::DimMismatch {
                    context: "replayed observation dimensionality",
                    expected: d,
                    got: o.x.len(),
                });
            }
            if let Some(g) = &o.grad {
                if g.len() != d {
                    return Err(Error::DimMismatch {
                        context: "replayed observation gradient",
                        expected: d,
                        got: g.len(),
                    });
                }
            }
        }
        if self.mt.is_some() {
            if let Some(o) = obs.iter().find(|o| o.grad.is_some()) {
                return Err(Error::Stream(format!(
                    "replayed observation (seq {}) carries a gradient but \
                     this model is multi-task — gradient observations are \
                     single-task only",
                    o.seq
                )));
            }
            let mut xs = Matrix::zeros(obs.len(), d);
            let mut ys = Vec::with_capacity(obs.len());
            let mut tasks = Vec::with_capacity(obs.len());
            for (i, o) in obs.iter().enumerate() {
                xs.row_mut(i).copy_from_slice(&o.x);
                ys.push(o.y);
                tasks.push(o.task);
            }
            return self.ingest_block_tasks(&xs, &ys, &tasks);
        }
        if let Some(o) = obs.iter().find(|o| o.task != 0) {
            return Err(Error::Stream(format!(
                "replayed observation names task {} but this model is \
                 single-task",
                o.task
            )));
        }
        if obs.is_empty() {
            return self.ingest_block(&Matrix::zeros(0, d), &[]);
        }
        let mut report: Option<IngestReport> = None;
        let mut start = 0usize;
        while start < obs.len() {
            let with_grad = obs[start].grad.is_some();
            let mut end = start + 1;
            while end < obs.len() && obs[end].grad.is_some() == with_grad {
                end += 1;
            }
            let chunk = &obs[start..end];
            let mut xs = Matrix::zeros(chunk.len(), d);
            let mut ys = Vec::with_capacity(chunk.len());
            for (i, o) in chunk.iter().enumerate() {
                xs.row_mut(i).copy_from_slice(&o.x);
                ys.push(o.y);
            }
            let r = if with_grad {
                let mut grads = Matrix::zeros(chunk.len(), d);
                for (i, o) in chunk.iter().enumerate() {
                    grads
                        .row_mut(i)
                        .copy_from_slice(o.grad.as_ref().expect("chunked on Some"));
                }
                self.ingest_block_grads(&xs, &ys, &grads)?
            } else {
                self.ingest_block(&xs, &ys)?
            };
            report = Some(match report {
                None => r,
                Some(acc) => merge_reports(acc, r),
            });
            start = end;
        }
        Ok(report.expect("non-empty observation list"))
    }

    /// Rebuild the grid scatter(s) from scratch (refresh path) — the
    /// same scatter [`PredictCache::build`] performs; multi-task states
    /// rebuild every task's masked scatter `Wᵀ(c_t ∘ α)`.
    fn rebuild_scatter(&mut self) {
        let Some(mt) = &self.mt else {
            self.wta = if self.has_any_grad() {
                // Extended rows: W_extᵀα through the operator's own row
                // list — value-only states keep the historical
                // `scatter_wt` call, whose accumulation order it matches
                // bitwise.
                self.op.wt_matvec(&self.alpha)
            } else {
                scatter_wt(&self.xs, &self.alpha, &self.axes)
            };
            return;
        };
        let s = mt.kernel.num_tasks();
        let mut scatters = Vec::with_capacity(s);
        for t in 0..s {
            let mask = mt.kernel.row_mask(t, &mt.task_of);
            let masked: Vec<f64> =
                self.alpha.iter().zip(&mask).map(|(&a, &c)| c * a).collect();
            scatters.push(scatter_wt(&self.xs, &masked, &self.axes));
        }
        self.wta = scatters.remove(0);
        self.mt.as_mut().expect("checked above").wtas = scatters;
    }

    /// Scatter the α delta of every materially-changed row into the grid
    /// scatter(s), then refresh the mean cache(s) with one
    /// Kronecker–Toeplitz apply each. Returns the number of rows whose
    /// stencil was touched. Multi-task states pay one stencil *decode*
    /// per touched row for all tasks — row i's delta lands in task t's
    /// scatter weighted by `k_task(t, task_of[i])`.
    fn patch_mean(&mut self, alpha_old: &[f64], n_old: usize) -> usize {
        let dims: Vec<usize> = self.axes.iter().map(|g| g.m).collect();
        let strides = tensor_strides(&dims);
        let scale = self
            .alpha
            .iter()
            .fold(0.0f64, |m, a| m.max(a.abs()));
        let eps = self.cfg.patch_eps * scale;
        let mut touched = 0usize;
        let mut wta = std::mem::take(&mut self.wta);
        let mut mt_wtas = match &mut self.mt {
            Some(mt) => std::mem::take(&mut mt.wtas),
            None => Vec::new(),
        };
        if self.has_any_grad() {
            // Extended rows (single-task only): walk the interleaved row
            // cursor — each point's value row, then its d derivative
            // rows when it carries a gradient. Appended rows are a
            // suffix, so `r < alpha_old.len()` identifies surviving
            // entries exactly as the value-only walk does.
            debug_assert!(self.mt.is_none(), "gradients are single-task only");
            let rows_old = alpha_old.len();
            let mut r = 0usize;
            for i in 0..self.xs.rows {
                let old = if r < rows_old { alpha_old[r] } else { 0.0 };
                let delta = self.alpha[r] - old;
                if delta != 0.0 && delta.abs() > eps {
                    touched += 1;
                    tensor_stencil(self.xs.row(i), &self.axes, &strides, |g, w| {
                        wta[g] += w * delta;
                    });
                }
                r += 1;
                if self.grads[i].is_some() {
                    for axis in 0..self.xs.cols {
                        let old = if r < rows_old { alpha_old[r] } else { 0.0 };
                        let delta = self.alpha[r] - old;
                        if delta != 0.0 && delta.abs() > eps {
                            touched += 1;
                            tensor_stencil_grad(
                                self.xs.row(i),
                                axis,
                                &self.axes,
                                &strides,
                                |g, w| {
                                    wta[g] += w * delta;
                                },
                            );
                        }
                        r += 1;
                    }
                }
            }
            debug_assert_eq!(r, self.alpha.len());
            self.wta = wta;
            self.cache.terms_mut()[0].mean =
                mean_from_scatter(&self.wta, &self.factors, &dims, self.hypers.sf2());
            return touched;
        }
        for i in 0..self.xs.rows {
            let old = if i < n_old { alpha_old[i] } else { 0.0 };
            let delta = self.alpha[i] - old;
            if delta == 0.0 || delta.abs() <= eps {
                continue;
            }
            touched += 1;
            match &self.mt {
                None => {
                    tensor_stencil(self.xs.row(i), &self.axes, &strides, |g, w| {
                        wta[g] += w * delta;
                    });
                }
                Some(mt) => {
                    let ti = mt.task_of[i];
                    let masks: Vec<f64> = (0..=mt_wtas.len())
                        .map(|t| mt.kernel.eval(t, ti))
                        .collect();
                    tensor_stencil(self.xs.row(i), &self.axes, &strides, |g, w| {
                        let wd = w * delta;
                        wta[g] += wd * masks[0];
                        for (wt, &c) in mt_wtas.iter_mut().zip(&masks[1..]) {
                            wt[g] += wd * c;
                        }
                    });
                }
            }
        }
        self.wta = wta;
        // One grid apply per cache (cached Toeplitz factors) — the same
        // formula the snapshot-time build uses.
        self.cache.terms_mut()[0].mean =
            mean_from_scatter(&self.wta, &self.factors, &dims, self.hypers.sf2());
        if let Some(mt) = &mut self.mt {
            mt.wtas = mt_wtas;
            for (cache, wt) in mt.caches.iter_mut().zip(&mt.wtas) {
                cache.terms_mut()[0].mean =
                    mean_from_scatter(wt, &self.factors, &dims, self.hypers.sf2());
            }
        }
        touched
    }

    /// Rebuild the full predictive cache(s) (mean + variance factor)
    /// from the current data and α. Multi-task states rebuild one masked
    /// cache per task from the shared inverse root of the *multi-task*
    /// K̂ = σ_f²·(K ∘ K_task) + σ_n²·I.
    fn rebuild_cache(&mut self) -> Result<()> {
        let s = match &self.cfg.variance {
            VarianceMode::None => None,
            VarianceMode::Exact => {
                let kern =
                    ProductKernel::rbf(self.xs.cols, self.hypers.ell(), self.hypers.sf2());
                let mut khat = if self.has_any_grad() {
                    // Dense derivative kernel over the extended rows —
                    // the exact K̂ the extended operator approximates.
                    kern.gram_deriv_sym(&self.xs, &self.grad_mask())
                } else {
                    kern.gram_sym(&self.xs)
                };
                if let Some(mt) = &self.mt {
                    for i in 0..khat.rows {
                        for j in 0..khat.cols {
                            let v = khat.get(i, j)
                                * mt.kernel.eval(mt.task_of[i], mt.task_of[j]);
                            khat.set(i, j, v);
                        }
                    }
                }
                khat.add_diag(self.hypers.sn2());
                Some(inverse_root_exact(&Cholesky::new_with_jitter(&khat, 0.0)?))
            }
            VarianceMode::Lanczos(rank) => {
                let rank = *rank;
                let probe = self.targets();
                Some(self.with_view(|view| inverse_root_lanczos(view, &probe, rank))?)
            }
        };
        let grid = RectilinearGrid::from_axes(self.axes.clone());
        if self.has_any_grad() {
            self.cache = build_grad_cache(
                &self.xs,
                &self.grad_mask(),
                &self.alpha,
                &self.hypers,
                crate::grid::GridSpec::Rectilinear(
                    self.axes.iter().map(|g| g.m).collect(),
                ),
                self.axes.clone(),
                s.as_ref(),
            )?;
            return Ok(());
        }
        match &self.mt {
            None => {
                self.cache = PredictCache::build(
                    &self.xs,
                    &self.alpha,
                    &self.hypers,
                    &grid,
                    s.as_ref(),
                )?;
            }
            Some(mt) => {
                let sf2 = self.hypers.sf2();
                let num = mt.kernel.num_tasks();
                let mut caches = Vec::with_capacity(num);
                for t in 0..num {
                    let mask = mt.kernel.row_mask(t, &mt.task_of);
                    caches.push(build_task_cache(
                        &self.xs,
                        &self.alpha,
                        &self.hypers,
                        &grid,
                        s.as_ref(),
                        &mask,
                        sf2 * mt.kernel.eval(t, t),
                    )?);
                }
                self.cache = caches.remove(0);
                self.mt.as_mut().expect("checked above").caches = caches;
            }
        }
        Ok(())
    }

    /// Predictive mean from the live cache (patched every ingest).
    pub fn predict_mean(&self, xtest: &Matrix) -> Vec<f64> {
        self.cache.predict_mean(xtest)
    }

    /// Gradient of the predictive mean (n* × d) from the live cache —
    /// the same grid buffer queried through derivative stencils, so it
    /// is as fresh as the mean (patched every ingest). Available on
    /// value-only states too: the posterior mean is differentiable
    /// whether or not gradients were observed.
    pub fn predict_grad(&self, xtest: &Matrix) -> Matrix {
        self.cache.predict_grad(xtest)
    }

    /// Latent predictive variance at solver grade: all test solves ride
    /// one block-CG call against the current operator (exact up to CG
    /// tolerance, unlike the rank-r cache variance). Single-task only —
    /// a bare test point carries no task id, so multi-task variances are
    /// served from the per-task caches ([`task_cache`](Self::task_cache)).
    pub fn predict_var(&self, xtest: &Matrix) -> Result<Vec<f64>> {
        if self.mt.is_some() {
            return Err(Error::Stream(
                "solver-grade predict_var is single-task only — multi-task \
                 variances are served from the per-task caches \
                 (predict <task> x…)"
                    .into(),
            ));
        }
        let kern =
            ProductKernel::rbf(self.xs.cols, self.hypers.ell(), self.hypers.sf2());
        let kx = if self.has_any_grad() {
            // Extended cross-covariance: derivative-kernel rows against
            // value-only test columns, matching the extended operator's
            // row count.
            kern.gram_deriv(
                &self.xs,
                &self.grad_mask(),
                xtest,
                &vec![false; xtest.rows],
            )
        } else {
            kern.gram(&self.xs, xtest)
        };
        let view = self.view();
        let pre = self.solve_precond();
        let sol = block_cg_solve_with(&view, &kx, pre.as_ref(), None, self.cg);
        Ok((0..xtest.rows)
            .map(|j| {
                let quad = dot(&kx.col(j), &sol.x.col(j));
                (self.hypers.sf2() - quad).max(1e-12)
            })
            .collect())
    }

    /// Freeze the live state into a serving snapshot; the pending log
    /// rides along (format v3), as do the α solve-space provenance
    /// (format v4), the multi-task head (format v5), and any pending
    /// gradient payloads (format v6).
    pub fn to_snapshot(&self) -> ModelSnapshot {
        ModelSnapshot {
            version: SNAPSHOT_VERSION,
            hypers: self.hypers,
            variant: SnapshotVariant::Kiss,
            train_rank: 0,
            refresh_rank: 0,
            alpha_space: self.grid_active as u32,
            alpha: self.alpha.clone(),
            cache: self.cache.clone(),
            pending: self.log.replay().cloned().collect(),
            tasks: self.mt.as_ref().map(|mt| TaskHead {
                kernel: mt.kernel.clone(),
                task_of: mt.task_of.clone(),
                caches: mt.caches.clone(),
            }),
        }
    }

    /// The live predictive cache (task 0's for multi-task states).
    pub fn cache(&self) -> &PredictCache {
        &self.cache
    }

    /// Number of tasks this state serves (1 for single-task).
    pub fn num_tasks(&self) -> usize {
        self.mt.as_ref().map_or(1, |mt| mt.kernel.num_tasks())
    }

    /// True iff this is a multi-task state.
    pub fn is_multitask(&self) -> bool {
        self.mt.is_some()
    }

    /// The live predictive cache serving `task`: task 0 is the base
    /// cache, tasks `1..s` their masked caches. `None` when out of
    /// range — including any task > 0 on a single-task state.
    pub fn task_cache(&self, task: usize) -> Option<&PredictCache> {
        if task == 0 {
            return Some(&self.cache);
        }
        self.mt.as_ref()?.caches.get(task - 1)
    }

    /// Current model size n.
    pub fn n(&self) -> usize {
        self.xs.rows
    }

    /// Input dimensionality d.
    pub fn dim(&self) -> usize {
        self.xs.cols
    }

    /// Pending (un-refreshed) observation count.
    pub fn pending(&self) -> usize {
        self.log.len()
    }

    /// Current solve α = K̂⁻¹y.
    pub fn alpha(&self) -> &[f64] {
        &self.alpha
    }

    /// Whether the per-ingest re-solves run in grid space (resolved from
    /// [`StreamConfig::space`] at the last refresh). Provenance for the
    /// serving snapshot — the recovered α agrees with the data-space
    /// solve to CG tolerance either way.
    pub fn solved_in_grid_space(&self) -> bool {
        self.grid_active
    }

    /// Model hyperparameters (fixed while streaming).
    pub fn hypers(&self) -> &GpHypers {
        &self.hypers
    }

    /// The frozen inducing-grid axes.
    pub fn axes(&self) -> &[Grid1d] {
        &self.axes
    }

    /// How many training points carry a gradient observation (0 for
    /// value-only states).
    pub fn num_grad_points(&self) -> usize {
        self.grads.iter().filter(|g| g.is_some()).count()
    }
}

/// Concatenate two chronologically-consecutive ingest reports (the
/// chunked replay of a mixed value/gradient pending log): counters sum,
/// outcomes concatenate, and the later report wins the point-in-time
/// fields (`n`, `pending`, `refreshed`).
fn merge_reports(a: IngestReport, b: IngestReport) -> IngestReport {
    let mut outcomes = a.outcomes;
    outcomes.extend(b.outcomes);
    IngestReport {
        outcomes,
        accepted: a.accepted + b.accepted,
        duplicates: a.duplicates + b.duplicates,
        solve_iters: a.solve_iters + b.solve_iters,
        iters_saved: a.iters_saved + b.iters_saved,
        rows_patched: a.rows_patched + b.rows_patched,
        var_rebuilt: a.var_rebuilt || b.var_rebuilt,
        refreshed: b.refreshed.or(a.refreshed),
        enrolled: a.enrolled + b.enrolled,
        n: b.n,
        pending: b.pending,
    }
}
