//! Streaming observation ingestion: online GP updates on a fixed
//! inducing grid.
//!
//! The serving layer ([`crate::serve`]) froze a trained model into its
//! predictive caches; this module makes that model *live*. Because SKI
//! pins the inducing grid, a new observation touches the model only
//! through one sparse interpolation-stencil row of `W` — so ingestion
//! never retrains:
//!
//! - [`log`] — the [`ObservationLog`]: an append-only ring of pending
//!   observations with bitwise dedup and chronological replay, persisted
//!   by snapshot format v3;
//! - [`state`] — the [`IncrementalState`]: extends `W`/`y` in place,
//!   re-solves `K̂α = y` with warm-started PCG (the cached refresh-time
//!   preconditioner rides along via
//!   [`crate::solvers::PaddedPrecond`]), patches the grid-side mean
//!   cache per stencil touch, rebuilds the variance factor when its
//!   tracked rank drift exceeds a budget, and escalates to a full
//!   [`IncrementalState::refresh`] per the every-N / ring-full /
//!   error-triggered policy.
//!
//! End to end, the TCP line protocol gains an `observe` verb (coalesced
//! with predicts by the request batcher), the CLI gains
//! `skip-gp observe` / `skip-gp serve --live`, and ingest latency,
//! warm-start savings, and cache patch-vs-rebuild counts surface as
//! `stream.*` metrics in the serving registry.
//!
//! ```
//! use skip_gp::gp::GpHypers;
//! use skip_gp::grid::Grid1d;
//! use skip_gp::linalg::Matrix;
//! use skip_gp::serve::VarianceMode;
//! use skip_gp::solvers::CgConfig;
//! use skip_gp::stream::{IncrementalState, StreamConfig};
//!
//! // A tiny 1-D model on a fixed 16-point grid…
//! let xs = Matrix::from_fn(24, 1, |i, _| i as f64 / 24.0);
//! let ys: Vec<f64> = (0..24).map(|i| (i as f64 / 4.0).sin()).collect();
//! let axes = vec![Grid1d::fit(0.0, 1.0, 16).unwrap()];
//! let cfg = StreamConfig { variance: VarianceMode::Exact, ..Default::default() };
//! let mut live = IncrementalState::new(
//!     xs, ys, GpHypers::new(0.4, 1.0, 0.01), axes, CgConfig::default(), cfg,
//! ).unwrap();
//!
//! // …ingests an observation without retraining.
//! let report = live.ingest(&[0.3125], (0.3125f64 * 6.0).sin()).unwrap();
//! assert_eq!(report.accepted, 1);
//! assert_eq!(live.n(), 25);
//! ```

pub mod log;
pub mod state;

pub use log::{Observation, ObservationLog, PushOutcome};
pub use state::{
    IncrementalState, IngestReport, RefreshReason, RowOutcome, StreamConfig, StreamStats,
};
