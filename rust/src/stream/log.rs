//! Append-only observation log: the ring of points ingested since the
//! last full refresh.
//!
//! Every observation accepted by the streaming path
//! ([`crate::stream::IncrementalState`]) is recorded here with a
//! monotonically increasing sequence number. The log serves three jobs:
//!
//! - **dedup** — a bitwise-identical `(task, x, y)` triple still in the
//!   ring is rejected, so client retries (the TCP protocol has no
//!   request ids) cannot double-count an observation;
//! - **chronological replay** — [`ObservationLog::replay`] walks the
//!   pending entries in ingest order, which is how a reloaded snapshot's
//!   pending section is re-applied to a live model;
//! - **bounded staleness** — the ring has a fixed capacity; when it
//!   fills, the refresh policy escalates to a full
//!   [`refresh`](crate::stream::IncrementalState::refresh), which absorbs
//!   (and clears) everything pending. Entries are never overwritten or
//!   dropped — "ring" bounds the *pending* set, not history.
//!
//! Snapshot format v3+ persists the pending entries verbatim
//! ([`crate::serve::snapshot`]), so a checkpointed live model does not
//! lose the observations streamed since its last refresh. Single-task
//! models carry `task == 0` everywhere, which keeps their dedup and
//! replay semantics identical to the pre-multi-task format.

use std::collections::HashSet;
use std::collections::VecDeque;

/// One streamed observation: task id (0 for single-task models), query
/// point, target, and its ingest sequence number (monotonic per log,
/// starting at 0).
#[derive(Clone, Debug, PartialEq)]
pub struct Observation {
    pub seq: u64,
    /// Task the observation belongs to (always 0 for single-task models).
    pub task: usize,
    pub x: Vec<f64>,
    pub y: f64,
    /// Optional gradient observation ∇y at `x` (D-SKI): `Some` entries
    /// carry d partial derivatives and make the refresh build the
    /// extended-row operator. Persisted by snapshot format v6+.
    pub grad: Option<Vec<f64>>,
}

/// Outcome of a [`ObservationLog::push`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Appended with this sequence number.
    Appended(u64),
    /// Bitwise duplicate of a pending entry — dropped.
    Duplicate,
}

/// Append-only ring of pending observations (see the module docs).
#[derive(Debug)]
pub struct ObservationLog {
    entries: VecDeque<Observation>,
    /// FNV hashes of the pending `(task, x, y)` payloads; collisions are
    /// resolved by an exact scan before declaring a duplicate.
    seen: HashSet<u64>,
    capacity: usize,
    next_seq: u64,
}

/// FNV-1a over the task id and the little-endian bytes of `(x, y)` — the
/// dedup key. The hash is internal (never persisted), so folding the
/// task id in costs nothing for single-task models beyond eight zero
/// bytes. A gradient payload, when present, is folded after a marker
/// word; observations without a gradient hash exactly as they always
/// have, so mixed logs dedup both kinds correctly.
fn payload_hash(task: usize, x: &[f64], y: f64, grad: Option<&[f64]>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat_bytes = |bytes: [u8; 8]| {
        for b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat_bytes((task as u64).to_le_bytes());
    for &v in x {
        eat_bytes(v.to_le_bytes());
    }
    eat_bytes(y.to_le_bytes());
    if let Some(g) = grad {
        // Marker distinguishes `(x, y, grad=[0.0; d])` from `(x, y)`.
        eat_bytes(u64::MAX.to_le_bytes());
        for &v in g {
            eat_bytes(v.to_le_bytes());
        }
    }
    h
}

impl ObservationLog {
    /// An empty log that escalates to a full refresh once `capacity`
    /// observations are pending.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "log capacity must be at least 1");
        ObservationLog {
            entries: VecDeque::new(),
            seen: HashSet::new(),
            capacity,
            next_seq: 0,
        }
    }

    /// Append `(task, x, y)` unless it bitwise-duplicates a pending
    /// entry. Callers check [`is_full`](Self::is_full) and refresh
    /// *after* the push that fills the ring — pushes themselves are never
    /// refused.
    pub fn push(&mut self, task: usize, x: &[f64], y: f64) -> PushOutcome {
        self.push_with_grad(task, x, y, None)
    }

    /// [`push`](Self::push) with an optional gradient payload; the
    /// gradient participates in dedup (same `(x, y)` with and without a
    /// gradient are distinct observations).
    pub fn push_with_grad(
        &mut self,
        task: usize,
        x: &[f64],
        y: f64,
        grad: Option<&[f64]>,
    ) -> PushOutcome {
        if self.contains_with_grad(task, x, y, grad) {
            return PushOutcome::Duplicate;
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.seen.insert(payload_hash(task, x, y, grad));
        self.entries.push_back(Observation {
            seq,
            task,
            x: x.to_vec(),
            y,
            grad: grad.map(<[f64]>::to_vec),
        });
        PushOutcome::Appended(seq)
    }

    /// True iff a bitwise-identical gradient-free `(task, x, y)` is
    /// pending.
    pub fn contains(&self, task: usize, x: &[f64], y: f64) -> bool {
        self.contains_with_grad(task, x, y, None)
    }

    /// True iff a bitwise-identical `(task, x, y, grad)` is pending.
    pub fn contains_with_grad(
        &self,
        task: usize,
        x: &[f64],
        y: f64,
        grad: Option<&[f64]>,
    ) -> bool {
        self.seen.contains(&payload_hash(task, x, y, grad))
            && self.entries.iter().any(|o| {
                o.task == task
                    && o.y.to_bits() == y.to_bits()
                    && bits_eq(&o.x, x)
                    && match (&o.grad, grad) {
                        (None, None) => true,
                        (Some(a), Some(b)) => bits_eq(a, b),
                        _ => false,
                    }
            })
    }

    /// Pending entries in chronological (sequence) order.
    pub fn replay(&self) -> impl Iterator<Item = &Observation> {
        self.entries.iter()
    }

    /// Mark everything pending as absorbed (a full refresh ran): clears
    /// the ring and the dedup window, keeps the sequence counter
    /// monotonic.
    pub fn absorb(&mut self) {
        self.entries.clear();
        self.seen.clear();
    }

    /// Restore pending entries (snapshot reload). Entries must be in
    /// chronological order; the sequence counter resumes past the last.
    pub fn restore(&mut self, entries: Vec<Observation>) {
        debug_assert!(entries.windows(2).all(|w| w[0].seq < w[1].seq));
        for o in &entries {
            self.seen
                .insert(payload_hash(o.task, &o.x, o.y, o.grad.as_deref()));
            self.next_seq = self.next_seq.max(o.seq + 1);
        }
        self.entries.extend(entries);
    }

    /// Pending entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True iff nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True iff the pending set has reached capacity (refresh now).
    pub fn is_full(&self) -> bool {
        self.entries.len() >= self.capacity
    }

    /// The ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Next sequence number to be assigned.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(u, v)| u.to_bits() == v.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_assigns_monotonic_seqs() {
        let mut log = ObservationLog::new(8);
        assert_eq!(log.push(0, &[0.1, 0.2], 1.0), PushOutcome::Appended(0));
        assert_eq!(log.push(0, &[0.3, 0.4], 2.0), PushOutcome::Appended(1));
        assert_eq!(log.len(), 2);
        let seqs: Vec<u64> = log.replay().map(|o| o.seq).collect();
        assert_eq!(seqs, vec![0, 1]);
    }

    #[test]
    fn bitwise_duplicates_are_dropped() {
        let mut log = ObservationLog::new(8);
        log.push(0, &[0.1, 0.2], 1.0);
        assert_eq!(log.push(0, &[0.1, 0.2], 1.0), PushOutcome::Duplicate);
        // Same x, different y is a fresh observation (a re-measurement).
        assert_eq!(log.push(0, &[0.1, 0.2], 1.5), PushOutcome::Appended(1));
        // -0.0 differs bitwise from 0.0: not a duplicate.
        log.push(0, &[0.0], 0.0);
        assert_eq!(log.push(0, &[-0.0], 0.0), PushOutcome::Appended(3));
        assert_eq!(log.len(), 4);
    }

    #[test]
    fn same_payload_different_task_is_not_a_duplicate() {
        let mut log = ObservationLog::new(8);
        log.push(1, &[0.1, 0.2], 1.0);
        // A different task observing the identical (x, y) is fresh data.
        assert_eq!(log.push(2, &[0.1, 0.2], 1.0), PushOutcome::Appended(1));
        // …while the same task retrying is deduped.
        assert_eq!(log.push(1, &[0.1, 0.2], 1.0), PushOutcome::Duplicate);
        assert!(log.contains(2, &[0.1, 0.2], 1.0));
        assert!(!log.contains(3, &[0.1, 0.2], 1.0));
    }

    #[test]
    fn absorb_clears_pending_but_not_seq() {
        let mut log = ObservationLog::new(4);
        log.push(0, &[1.0], 2.0);
        log.push(0, &[2.0], 3.0);
        log.absorb();
        assert!(log.is_empty());
        // Absorbed entries no longer shadow re-observations…
        assert_eq!(log.push(0, &[1.0], 2.0), PushOutcome::Appended(2));
        // …and sequence numbers never restart.
        assert_eq!(log.next_seq(), 3);
    }

    #[test]
    fn fills_at_capacity() {
        let mut log = ObservationLog::new(2);
        log.push(0, &[1.0], 0.0);
        assert!(!log.is_full());
        log.push(0, &[2.0], 0.0);
        assert!(log.is_full());
    }

    #[test]
    fn gradient_payload_participates_in_dedup() {
        let mut log = ObservationLog::new(8);
        let x = [0.1, 0.2];
        let g = [3.0, -4.0];
        assert_eq!(
            log.push_with_grad(0, &x, 1.0, Some(&g)),
            PushOutcome::Appended(0)
        );
        // Exact retry (same gradient) is deduped…
        assert_eq!(
            log.push_with_grad(0, &x, 1.0, Some(&g)),
            PushOutcome::Duplicate
        );
        // …but the same (x, y) without a gradient is a fresh observation,
        assert_eq!(log.push(0, &x, 1.0), PushOutcome::Appended(1));
        // …as is a zero gradient (the hash marker keeps it distinct from
        // the gradient-free entry).
        assert_eq!(
            log.push_with_grad(0, &x, 1.0, Some(&[0.0, 0.0])),
            PushOutcome::Appended(2)
        );
        assert!(log.contains_with_grad(0, &x, 1.0, Some(&g)));
        assert!(!log.contains_with_grad(0, &x, 1.0, Some(&[3.0, 4.0])));
        assert!(log.contains(0, &x, 1.0));
    }

    #[test]
    fn restore_resumes_sequence() {
        let mut log = ObservationLog::new(8);
        log.restore(vec![
            Observation { seq: 3, task: 0, x: vec![0.5], y: 1.0, grad: None },
            Observation { seq: 7, task: 1, x: vec![0.6], y: 2.0, grad: None },
        ]);
        assert_eq!(log.len(), 2);
        assert!(log.contains(0, &[0.5], 1.0));
        assert!(log.contains(1, &[0.6], 2.0));
        assert_eq!(log.push(0, &[0.7], 3.0), PushOutcome::Appended(8));
    }
}
