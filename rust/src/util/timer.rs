//! Lightweight wall-clock timing for the benchmark harness.

use std::time::Instant;

/// A simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_s())
}

/// Run a closure repeatedly until `min_time_s` has elapsed (at least
/// `min_iters` times) and report the median per-iteration seconds.
/// This is the measurement core of our criterion-free bench harness.
pub fn bench_median_s(
    min_iters: usize,
    min_time_s: f64,
    mut f: impl FnMut(),
) -> f64 {
    let mut samples = Vec::new();
    let overall = Timer::start();
    loop {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
        if samples.len() >= min_iters && overall.elapsed_s() >= min_time_s {
            break;
        }
        // Hard cap so pathological cases cannot hang the harness.
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn bench_runs_min_iters() {
        let mut count = 0usize;
        let med = bench_median_s(5, 0.0, || count += 1);
        assert!(count >= 5);
        assert!(med >= 0.0);
    }
}
