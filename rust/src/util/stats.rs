//! Error metrics and small statistical helpers used by the harness.

/// Mean absolute error between predictions and targets.
pub fn mae(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    assert!(!pred.is_empty());
    pred.iter()
        .zip(target)
        .map(|(p, t)| (p - t).abs())
        .sum::<f64>()
        / pred.len() as f64
}

/// Root mean squared error.
pub fn rmse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    assert!(!pred.is_empty());
    (pred.iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64)
        .sqrt()
}

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (population, ddof=0).
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Relative L2 error ‖a−b‖ / ‖b‖.
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt();
    let den: f64 = b.iter().map(|y| y * y).sum::<f64>().sqrt();
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

/// Ordinary least squares slope of y against x (for log-log scaling fits).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    sxy / sxx
}

/// Standardization transform (z-scoring) fitted on training data.
#[derive(Clone, Debug)]
pub struct Standardizer {
    pub mean: f64,
    pub std: f64,
}

impl Standardizer {
    /// Fit on values; guards against zero variance.
    pub fn fit(xs: &[f64]) -> Self {
        let m = mean(xs);
        let s = std_dev(xs).max(1e-12);
        Standardizer { mean: m, std: s }
    }

    pub fn transform(&self, x: f64) -> f64 {
        (x - self.mean) / self.std
    }

    pub fn inverse(&self, z: f64) -> f64 {
        z * self.std + self.mean
    }

    pub fn transform_vec(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.transform(x)).collect()
    }

    pub fn inverse_vec(&self, zs: &[f64]) -> Vec<f64> {
        zs.iter().map(|&z| self.inverse(z)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mae_rmse_basic() {
        let p = [1.0, 2.0, 3.0];
        let t = [1.0, 3.0, 5.0];
        assert!((mae(&p, &t) - 1.0).abs() < 1e-12);
        assert!((rmse(&p, &t) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn rel_err_zero_for_equal() {
        let a = [1.0, -2.0, 3.0];
        assert_eq!(rel_err(&a, &a), 0.0);
    }

    #[test]
    fn ols_slope_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 2.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn standardizer_roundtrip() {
        let xs = [3.0, 5.0, 9.0, 11.0];
        let s = Standardizer::fit(&xs);
        let zs = s.transform_vec(&xs);
        assert!(mean(&zs).abs() < 1e-12);
        let back = s.inverse_vec(&zs);
        for (a, b) in back.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
