//! Minimal fork-join parallelism on `std::thread::scope`.
//!
//! No rayon is available in the offline build environment, so the batched
//! MVM engine uses these helpers for its embarrassingly parallel loops:
//! columns of a multi-RHS block, components of a SKIP merge level, terms
//! of a `SumOp`, row chunks of a dense kernel. They are deliberately tiny:
//! ordered results, contiguous chunking, and a sequential fallback below
//! a work threshold so small problems never pay thread-spawn latency.

/// Number of worker threads the helpers will fan out to.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel map over `items`, preserving order.
///
/// Falls back to a plain sequential map when `items.len() < min_parallel`
/// or only one hardware thread is available.
pub fn par_map<T, R, F>(items: &[T], min_parallel: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let nt = num_threads().min(items.len().max(1));
    if nt <= 1 || items.len() < min_parallel.max(2) {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(nt);
    let mut out: Vec<Option<R>> = Vec::new();
    out.resize_with(items.len(), || None);
    let f = &f;
    std::thread::scope(|s| {
        for (in_chunk, out_chunk) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || {
                for (item, slot) in in_chunk.iter().zip(out_chunk.iter_mut()) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("par_map worker panicked")).collect()
}

/// Parallel map over an index range `0..len`, preserving order.
pub fn par_map_range<R, F>(len: usize, min_parallel: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let idx: Vec<usize> = (0..len).collect();
    par_map(&idx, min_parallel, |&i| f(i))
}

/// Split `buf` into per-thread contiguous chunks of whole rows
/// (`row_width` elements each) and run `f(first_row_index, chunk)` on each
/// chunk in parallel. Used to fill the rows of a row-major output matrix
/// without any locking: chunks are disjoint `&mut` slices.
///
/// `min_rows_per_thread` throttles the fan-out so tiny matrices stay
/// sequential.
pub fn par_row_chunks<F>(
    buf: &mut [f64],
    row_width: usize,
    min_rows_per_thread: usize,
    f: F,
) where
    F: Fn(usize, &mut [f64]) + Sync,
{
    assert!(row_width > 0);
    debug_assert_eq!(buf.len() % row_width, 0);
    let rows = buf.len() / row_width;
    let nt = num_threads()
        .min(rows / min_rows_per_thread.max(1))
        .max(1);
    if nt <= 1 {
        f(0, buf);
        return;
    }
    let rows_per = rows.div_ceil(nt);
    let f = &f;
    std::thread::scope(|s| {
        for (ci, chunk) in buf.chunks_mut(rows_per * row_width).enumerate() {
            s.spawn(move || f(ci * rows_per, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let got = par_map(&xs, 1, |&x| x * x);
        let want: Vec<usize> = xs.iter().map(|&x| x * x).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn par_map_sequential_fallback() {
        let xs = [1, 2, 3];
        assert_eq!(par_map(&xs, 100, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn par_map_range_matches_loop() {
        let got = par_map_range(37, 1, |i| i as f64 * 0.5);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i as f64 * 0.5);
        }
    }

    #[test]
    fn par_row_chunks_covers_all_rows() {
        let (rows, width) = (64, 5);
        let mut buf = vec![0.0; rows * width];
        par_row_chunks(&mut buf, width, 1, |first_row, chunk| {
            for (r, row) in chunk.chunks_mut(width).enumerate() {
                for v in row.iter_mut() {
                    *v = (first_row + r) as f64;
                }
            }
        });
        for (i, row) in buf.chunks(width).enumerate() {
            assert!(row.iter().all(|&v| v == i as f64), "row {i}: {row:?}");
        }
    }

    #[test]
    fn par_row_chunks_empty_is_noop() {
        let mut buf: Vec<f64> = Vec::new();
        par_row_chunks(&mut buf, 3, 1, |_, _| {});
    }
}
