//! Deterministic pseudo-random number generation.
//!
//! No external `rand` crate is available in this environment, so we carry a
//! small, well-known generator: **xoshiro256++** (Blackman & Vigna, 2019)
//! seeded through SplitMix64, with Box–Muller for normal variates.
//! Everything downstream of an experiment seed is fully reproducible.

/// xoshiro256++ PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller draw.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-cryptographic) purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u1 == 0 exactly.
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Vector of n standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of n uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Rademacher ±1 vector (probe vectors for stochastic trace estimation).
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A derived generator (for spawning independent streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let xs = r.uniform_vec(20_000, 0.0, 1.0);
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn rademacher_is_pm_one() {
        let mut r = Rng::new(9);
        let xs = r.rademacher_vec(1000);
        assert!(xs.iter().all(|&x| x == 1.0 || x == -1.0));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.1);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "f2={f2}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(123);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
