//! Utility substrate: PRNG, statistics, timing.

pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::{mae, mean, ols_slope, rel_err, rmse, std_dev, Standardizer};
pub use timer::{bench_median_s, timed, Timer};
