//! Utility substrate: PRNG, statistics, timing, fork-join parallelism.

pub mod parallel;
pub mod rng;
pub mod stats;
pub mod timer;

pub use parallel::{num_threads, par_map, par_map_range, par_row_chunks};
pub use rng::Rng;
pub use stats::{mae, mean, ols_slope, rel_err, rmse, std_dev, Standardizer};
pub use timer::{bench_median_s, timed, Timer};
