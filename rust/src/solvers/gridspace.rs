//! Grid-space normal-equations CG: covariance solves whose per-iteration
//! cost is independent of n (Yadav, Sheldon & Musco 2021, §3).
//!
//! Every data-space CG iteration against the SKI covariance
//! `K̂ = σ_f² W K W ᵀ + σ_n² I` walks all n stencil rows of `W` twice.
//! But after a **one-time** O(n) projection of the data through `W`, the
//! solve can run entirely on the m grid points. With `G = WᵀW` (the
//! precomputed stencil-overlap Gram, [`StencilGram`]) define
//!
//! ```text
//! B = σ_f²·K·G + σ_n²·I          (m × m, nonsymmetric)
//! ```
//!
//! and solve `B q = c` with `c = σ_f²·K·(Wᵀy)`. Then
//!
//! ```text
//! α = (y − W q) / σ_n²
//! ```
//!
//! satisfies `K̂ α = y` *exactly* (substitute and use `Wᵀ W = G`: the
//! defect is `W·(c − B q)/σ_n² = 0`). Per iteration the solve costs one
//! Kronecker–Toeplitz apply (O(M log m)) plus one Gram apply (O(M·7ᵈ))
//! — no term that grows with n.
//!
//! **Why CG applies.** `B` is not symmetric, but it is self-adjoint in
//! the `G`-semi-inner-product `⟨u, v⟩_G = uᵀGv`: `GB = σ_f²·G·K·G +
//! σ_n²·G` is symmetric, and
//!
//! ```text
//! ⟨u, B u⟩_G = σ_f²·(Gu)ᵀK(Gu) + σ_n²·uᵀGu = (W u)ᵀ K̂ (W u) ≥ 0
//! ```
//!
//! — positive-semidefinite through the *covariance* `K̂`, not the grid
//! kernel, so the iteration is well defined even for the **signed**
//! combination-technique terms of a sparse grid (where `K` alone is
//! indefinite). Components in `null(W)` are invisible to the seminorm
//! and provably irrelevant: they never change the recovered α (which
//! only sees `W q`).
//!
//! **Convergence criterion.** The grid residual maps to the data residual
//! exactly: `y − K̂ α̂ = −W r/σ_n²`, hence `‖data residual‖ = ‖r‖_G/σ_n²`.
//! The solver therefore stops when `‖r‖_G ≤ tol·σ_n²·‖y‖` — the same
//! `‖K̂x − y‖ ≤ tol·‖y‖` certificate unpreconditioned data-space CG
//! provides, so the two spaces are interchangeable at equal `tol`.
//!
//! Grid solves run *unpreconditioned*: the grid dimension M is fixed as
//! data streams in (`append_rows` only touches `G` and `Wᵀy`), so warm
//! starts carry across resolves with no dimension padding — the
//! grid-space translation of the data path's `PaddedPrecond`.
//!
//! Solver effort is recorded as `solver.gridcg.*` with a
//! `solver.space.grid` counter, next to the data-space solvers in the
//! metrics summary.

use super::cg::CgConfig;
use super::refine::{
    dot32, to_f64, Precision, INNER_TOL, MAX_OUTER, MIN_CONTRACTION,
};
use crate::linalg::{axpy, dot, norm2};
use crate::operators::kronecker::GramF32;
use crate::operators::{KronSkiF32, KroneckerSkiOp};
use crate::{Error, Result};
use std::sync::Arc;

/// The grid-space normal-equations system for a (possibly multi-term)
/// SKI covariance `K̂ = σ_f²·Σ_t c_t W_t K_t W_tᵀ + σ_n²·I`.
///
/// Terms share their [`KroneckerSkiOp`]s with the data-space covariance
/// view through `Arc` (see `crate::operators::ArcOp`), so both solve
/// spaces are backed by float-identical kernel arithmetic. Grid vectors
/// are the per-term grids stacked: `[q_1; …; q_T]`, M = Σ_t M_t.
///
/// - Single-term (dense KISS) systems apply `G = WᵀW` through the
///   precomputed banded [`StencilGram`] — O(M·7ᵈ) per apply, independent
///   of n.
/// - Multi-term (sparse-grid) systems apply the block Gram
///   `G = W_bigᵀW_big` as the composition `u ↦ Wᵀ(W u)` through one
///   shared data-space accumulator — still one pass, but O(n·s·T): exact,
///   not n-independent. The flat-in-n guarantee is the single-term
///   path's (see `docs/SOLVERS.md` for the decision table).
///
/// [`StencilGram`]: crate::operators::kronecker::StencilGram
pub struct GridSystem {
    /// `(c_t, op_t)` combination coefficient + per-term operator.
    terms: Vec<(f64, Arc<KroneckerSkiOp>)>,
    /// Start offset of each term's block in the stacked grid vector,
    /// plus the total as a final sentinel.
    offsets: Vec<usize>,
    m_big: usize,
    n: usize,
    sf2: f64,
    sn2: f64,
}

impl GridSystem {
    /// Build from the covariance's term decomposition. Fails with
    /// [`Error::Grid`] on degenerate axes, or (single-term) when the
    /// `WᵀW` band exceeds its storage budget — callers on the `Auto`
    /// space setting fall back to data-space CG on that error.
    pub fn new(terms: Vec<(f64, Arc<KroneckerSkiOp>)>, sf2: f64, sn2: f64) -> Result<Self> {
        if terms.is_empty() {
            return Err(Error::Grid("grid system needs at least one term".into()));
        }
        if !(sn2.is_finite() && sn2 > 0.0) {
            return Err(Error::Grid(format!(
                "grid-space solves need a positive noise σ_n² (got {sn2})"
            )));
        }
        let n = terms[0].1.dim();
        let mut offsets = Vec::with_capacity(terms.len() + 1);
        let mut m_big = 0usize;
        for (_, op) in &terms {
            if op.dim() != n {
                return Err(Error::Grid(
                    "grid-system terms disagree on the data size".into(),
                ));
            }
            offsets.push(m_big);
            m_big += op.total_grid;
        }
        offsets.push(m_big);
        if terms.len() == 1 {
            // Build (and validate) the banded Gram once, up front.
            terms[0].1.grid_space_op()?;
        } else {
            // Multi-term systems apply G by composition; still refuse
            // degenerate hand-built axes up front.
            for (t, (_, op)) in terms.iter().enumerate() {
                for (k, g) in op.grids.iter().enumerate() {
                    if g.m == 0 || !g.h.is_finite() || g.h <= 0.0 {
                        return Err(Error::Grid(format!(
                            "degenerate axis {k} in term {t} (m={}, h={})",
                            g.m, g.h
                        )));
                    }
                }
            }
        }
        Ok(GridSystem { terms, offsets, m_big, n, sf2, sn2 })
    }

    /// Stacked grid dimension M = Σ_t M_t.
    pub fn grid_dim(&self) -> usize {
        self.m_big
    }

    /// Data dimension n.
    pub fn data_dim(&self) -> usize {
        self.n
    }

    /// Noise σ_n² of the covariance this system solves.
    pub fn noise(&self) -> f64 {
        self.sn2
    }

    /// `Wᵀ v`: stack the per-term scatters (O(n·s) — the one-time
    /// projection; the iteration never calls this).
    pub fn wt(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.n);
        let mut out = Vec::with_capacity(self.m_big);
        for (_, op) in &self.terms {
            out.extend_from_slice(&op.wt_matvec(v));
        }
        out
    }

    /// `W u`: sum of per-term gathers (data-sized; used by the α
    /// back-projection, once per solve).
    pub fn w(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.m_big);
        let mut out = vec![0.0; self.n];
        for (t, (_, op)) in self.terms.iter().enumerate() {
            let block = &u[self.offsets[t]..self.offsets[t + 1]];
            let part = op.w_matvec(block);
            for (o, x) in out.iter_mut().zip(part) {
                *o += x;
            }
        }
        out
    }

    /// `G u = WᵀW u`: banded Gram for single-term systems, gather/scatter
    /// composition for multi-term ones.
    pub fn apply_g(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.m_big);
        if self.terms.len() == 1 {
            let gram = self.terms[0]
                .1
                .grid_space_op()
                .expect("validated at construction");
            return gram.apply(u);
        }
        self.wt(&self.w(u))
    }

    /// Block grid kernel `K u`: per term `c_t·σ_t²·(⊗K_t) u_t`, stacked.
    pub fn apply_k(&self, u: &[f64]) -> Vec<f64> {
        assert_eq!(u.len(), self.m_big);
        let mut out = Vec::with_capacity(self.m_big);
        for (t, (coeff, op)) in self.terms.iter().enumerate() {
            let block = &u[self.offsets[t]..self.offsets[t + 1]];
            let mut part = op.kron_matvec(block);
            let scale = coeff * op.outputscale();
            if scale != 1.0 {
                for p in part.iter_mut() {
                    *p *= scale;
                }
            }
            out.extend_from_slice(&part);
        }
        out
    }

    /// `B u = σ_f²·K·(G u) + σ_n²·u`, reusing a caller-held `G u`
    /// (the CG loop maintains `G p` by recurrence, so each iteration
    /// pays exactly one fresh `G` apply and one `K` apply).
    fn apply_b_given_g(&self, u: &[f64], gu: &[f64]) -> Vec<f64> {
        let mut out = self.apply_k(gu);
        for (o, &uu) in out.iter_mut().zip(u) {
            *o = self.sf2 * *o + self.sn2 * uu;
        }
        out
    }

    /// Right-hand side `c = σ_f²·K·wty` from a (possibly incrementally
    /// maintained) projection `wty = Wᵀy`.
    pub fn rhs_from_wty(&self, wty: &[f64]) -> Vec<f64> {
        let mut c = self.apply_k(wty);
        for v in c.iter_mut() {
            *v *= self.sf2;
        }
        c
    }

    /// Back-projection `α = (y − W q)/σ_n²` — exact for the exact q.
    pub fn recover_alpha(&self, y: &[f64], q: &[f64]) -> Vec<f64> {
        let wq = self.w(q);
        y.iter()
            .zip(&wq)
            .map(|(yi, wi)| (yi - wi) / self.sn2)
            .collect()
    }

    /// Translate a data-space solution into a grid-space warm seed: the
    /// exact α satisfies `W q = y − σ_n² α = σ_f² W K Wᵀ α`, so
    /// `q = σ_f²·K·(Wᵀα)` up to an irrelevant `null(W)` component.
    pub fn seed_from_alpha(&self, alpha: &[f64]) -> Vec<f64> {
        self.rhs_from_wty(&self.wt(alpha))
    }
}

/// Grid-space solve result: the recovered data-space α plus the grid
/// iterate `v` (the warm-start seed for the next solve against this or a
/// nearby system — grid dimension is stable across streaming appends).
#[derive(Clone, Debug)]
pub struct GridSolution {
    /// `α = K̂⁻¹ y` recovered by back-projection.
    pub alpha: Vec<f64>,
    /// The grid iterate q at exit.
    pub v: Vec<f64>,
    /// Iterations run.
    pub iters: usize,
    /// Final data-equivalent relative residual `‖K̂α − y‖/‖y‖`
    /// (= `‖r‖_G/(σ_n²·‖y‖)` — an exact identity, not an estimate).
    pub rel_residual: f64,
    /// Whether the tolerance was met.
    pub converged: bool,
}

/// Solve `K̂ α = y` in grid space. Convenience wrapper over
/// [`grid_cg_solve_with_wty`] that pays the O(n) projection itself.
pub fn grid_cg_solve(
    sys: &GridSystem,
    y: &[f64],
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> GridSolution {
    let wty = sys.wt(y);
    grid_cg_solve_with_wty(sys, y, &wty, x0, cfg)
}

/// Solve `K̂ α = y` in grid space with a caller-maintained projection
/// `wty = Wᵀy` (the streaming layer updates it incrementally per
/// ingested point instead of re-scattering all n rows).
///
/// `x0` is a *grid* seed (length M): the previous solve's
/// [`GridSolution::v`], or [`GridSystem::seed_from_alpha`] of a
/// data-space α. Mismatched lengths are dropped (cold start), mirroring
/// [`cg_solve_with`](super::cg_solve_with); a seed already inside
/// tolerance returns bitwise with 0 iterations.
///
/// [`CgConfig::precision`] routes the arithmetic exactly as in data
/// space: `F64` runs the recurrence below bitwise unchanged, `Mixed`
/// runs f32 inner grid iterations (f32 Gram band + f32 Toeplitz
/// spectra) under an f64 refinement loop that certifies on the same
/// `‖r‖_G ≤ tol·σ_n²·‖y‖` threshold.
pub fn grid_cg_solve_with_wty(
    sys: &GridSystem,
    y: &[f64],
    wty: &[f64],
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> GridSolution {
    match cfg.precision {
        Precision::F64 => grid_cg_solve_f64(sys, y, wty, x0, cfg),
        Precision::Mixed => grid_refined_solve(sys, y, wty, x0, cfg),
    }
}

/// The f64 grid-space recurrence behind [`grid_cg_solve_with_wty`] —
/// also the certifying fallback of the mixed path, reached without
/// re-entering the precision router.
fn grid_cg_solve_f64(
    sys: &GridSystem,
    y: &[f64],
    wty: &[f64],
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> GridSolution {
    let m = sys.grid_dim();
    assert_eq!(y.len(), sys.data_dim());
    assert_eq!(wty.len(), m);
    let g = crate::coordinator::metrics::global();
    g.incr("solver.space.grid", 1);
    let ny = norm2(y);
    if ny == 0.0 {
        crate::coordinator::metrics::record_solver("gridcg", 0, true);
        return GridSolution {
            alpha: vec![0.0; sys.data_dim()],
            v: vec![0.0; m],
            iters: 0,
            rel_residual: 0.0,
            converged: true,
        };
    }
    // ‖r‖_G ≤ tol·σ_n²·‖y‖ ⇔ ‖K̂α̂ − y‖ ≤ tol·‖y‖ (see module docs).
    let threshold = cfg.tol * sys.noise() * ny;
    let denom = sys.noise() * ny;
    let c = sys.rhs_from_wty(wty);
    let x0 = x0.filter(|x| x.len() == m);
    let seeded = x0.is_some();
    if seeded {
        g.incr("solver.warm.seeded", 1);
    }
    let (mut x, mut r) = match x0 {
        Some(x0) => {
            let gx = sys.apply_g(x0);
            let bx = sys.apply_b_given_g(x0, &gx);
            let r: Vec<f64> = c.iter().zip(&bx).map(|(ci, bi)| ci - bi).collect();
            (x0.to_vec(), r)
        }
        None => (vec![0.0; m], c.clone()),
    };
    let mut gr = sys.apply_g(&r);
    let mut rz = dot(&r, &gr).max(0.0);
    if rz.sqrt() <= threshold {
        // Inside tolerance at entry: a warm seed is returned bitwise
        // (iters = 0), and a cold zero-G-norm RHS is solved exactly by
        // q = c/σ_n² (then `B q = σ_f²·K·G·c/σ_n² + c = c` since
        // `G c = Wᵀ(W c) = 0`).
        if seeded {
            g.incr("solver.warm.hit", 1);
        } else if rz == 0.0 {
            for (xi, &ci) in x.iter_mut().zip(&c) {
                *xi = ci / sys.noise();
            }
        }
        crate::coordinator::metrics::record_solver("gridcg", 0, true);
        let alpha = sys.recover_alpha(y, &x);
        return GridSolution {
            alpha,
            v: x,
            iters: 0,
            rel_residual: rz.sqrt() / denom,
            converged: true,
        };
    }
    let mut p = r.clone();
    let mut gp = gr.clone();
    let mut iters = 0;
    let mut converged = false;
    for _ in 0..cfg.max_iters {
        iters += 1;
        let bp = sys.apply_b_given_g(&p, &gp);
        // ⟨p, Bp⟩_G = (W p)ᵀ K̂ (W p) > 0 for any p with W p ≠ 0.
        let pbp = dot(&gp, &bp);
        if pbp <= 0.0 {
            break;
        }
        let alpha_step = rz / pbp;
        axpy(alpha_step, &p, &mut x);
        axpy(-alpha_step, &bp, &mut r);
        gr = sys.apply_g(&r);
        let rz_new = dot(&r, &gr).max(0.0);
        if rz_new.sqrt() <= threshold {
            rz = rz_new;
            converged = true;
            break;
        }
        let beta = rz_new / rz;
        for (pi, &ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        // G p' = G r + β·G p by linearity: no extra Gram apply.
        for (gpi, &gri) in gp.iter_mut().zip(&gr) {
            *gpi = gri + beta * *gpi;
        }
        rz = rz_new;
    }
    let rel = rz.sqrt() / denom;
    let converged = converged || rel <= cfg.tol;
    crate::coordinator::metrics::record_solver("gridcg", iters, converged);
    let alpha = sys.recover_alpha(y, &x);
    GridSolution { alpha, v: x, iters, rel_residual: rel, converged }
}

/// Per-solve f32 view of a [`GridSystem`]: the banded Gram's f32 band
/// (single-term) or per-term f32 stencil views (multi-term composition),
/// plus the f32 Toeplitz spectra cached inside each factor.
struct GridSystemF32<'a> {
    sys: &'a GridSystem,
    /// Per-term stencil views — only built for multi-term systems, where
    /// `G` is applied as the composition `Wᵀ(W u)`.
    views: Vec<KronSkiF32<'a>>,
    /// Banded `WᵀW` in f32 — the single-term fast path.
    gram: Option<GramF32<'a>>,
    /// Per-term `c_t · σ_t²` as f32.
    kscales: Vec<f32>,
    sf2: f32,
    sn2: f32,
}

impl<'a> GridSystemF32<'a> {
    fn new(sys: &'a GridSystem) -> Self {
        let gram = if sys.terms.len() == 1 {
            Some(
                sys.terms[0]
                    .1
                    .grid_space_op()
                    .expect("validated at construction")
                    .f32_view(),
            )
        } else {
            None
        };
        let views = if gram.is_some() {
            Vec::new()
        } else {
            sys.terms.iter().map(|(_, op)| op.f32_view()).collect()
        };
        let kscales = sys
            .terms
            .iter()
            .map(|(c, op)| (c * op.outputscale()) as f32)
            .collect();
        GridSystemF32 {
            sys,
            views,
            gram,
            kscales,
            sf2: sys.sf2 as f32,
            sn2: sys.sn2 as f32,
        }
    }

    /// `G u` in f32 (banded or composed — mirrors [`GridSystem::apply_g`]).
    fn apply_g_f32(&self, u: &[f32]) -> Vec<f32> {
        if let Some(gram) = &self.gram {
            return gram.apply_f32(u);
        }
        let mut data = vec![0.0f32; self.sys.n];
        for (t, view) in self.views.iter().enumerate() {
            let block = &u[self.sys.offsets[t]..self.sys.offsets[t + 1]];
            for (o, x) in data.iter_mut().zip(view.w_matvec_f32(block)) {
                *o += x;
            }
        }
        let mut out = Vec::with_capacity(self.sys.m_big);
        for view in &self.views {
            out.extend_from_slice(&view.wt_matvec_f32(&data));
        }
        out
    }

    /// `B u = σ_f²·K·gu + σ_n²·u` in f32, with a caller-held `gu = G u`.
    fn apply_b_given_g_f32(&self, u: &[f32], gu: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.sys.m_big);
        for (t, (_, op)) in self.sys.terms.iter().enumerate() {
            let block = &gu[self.sys.offsets[t]..self.sys.offsets[t + 1]];
            let mut part = op.kron_matvec_f32(block);
            let scale = self.kscales[t];
            if scale != 1.0 {
                for p in part.iter_mut() {
                    *p *= scale;
                }
            }
            out.extend_from_slice(&part);
        }
        for (o, &uu) in out.iter_mut().zip(u) {
            *o = self.sf2 * *o + self.sn2 * uu;
        }
        out
    }
}

/// Inner f32 grid CG: solves `B d ≈ r` to [`INNER_TOL`] in the G-norm,
/// f64-accumulated scalars — the grid-space analogue of the inner solve
/// in [`super::refine`]. Unpreconditioned, exactly like the f64 grid
/// recurrence. Returns the correction in f64 plus iterations run.
fn inner_grid_cg_f32(f: &GridSystemF32, r: &[f64], max_iters: usize) -> (Vec<f64>, usize) {
    let m = r.len();
    let mut resid: Vec<f32> = r.iter().map(|&v| v as f32).collect();
    let mut x = vec![0.0f32; m];
    let mut gr = f.apply_g_f32(&resid);
    let mut rz = dot32(&resid, &gr).max(0.0);
    let bnorm = rz.sqrt();
    if bnorm == 0.0 || !bnorm.is_finite() {
        return (to_f64(&x), 0);
    }
    let mut p = resid.clone();
    let mut gp = gr.clone();
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        let bp = f.apply_b_given_g_f32(&p, &gp);
        let pbp = dot32(&gp, &bp);
        if pbp.is_nan() || pbp <= 0.0 {
            break;
        }
        let alpha = (rz / pbp) as f32;
        for (xi, &pi) in x.iter_mut().zip(&p) {
            *xi += alpha * pi;
        }
        for (ri, &bi) in resid.iter_mut().zip(&bp) {
            *ri -= alpha * bi;
        }
        gr = f.apply_g_f32(&resid);
        let rz_new = dot32(&resid, &gr).max(0.0);
        if rz_new.sqrt() <= INNER_TOL * bnorm {
            break;
        }
        let beta = (rz_new / rz) as f32;
        for (pi, &ri) in p.iter_mut().zip(&resid) {
            *pi = ri + beta * *pi;
        }
        for (gpi, &gri) in gp.iter_mut().zip(&gr) {
            *gpi = gri + beta * *gpi;
        }
        rz = rz_new;
    }
    (to_f64(&x), iters)
}

/// Mixed-precision grid solve: f32 inner grid CG sweeps under an f64
/// refinement loop certifying the same `‖r‖_G ≤ tol·σ_n²·‖y‖` threshold
/// as [`grid_cg_solve_f64`]. Stalls and sweep-budget exhaustion fall
/// back to the f64 recurrence seeded with the refined iterate.
fn grid_refined_solve(
    sys: &GridSystem,
    y: &[f64],
    wty: &[f64],
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> GridSolution {
    let m = sys.grid_dim();
    assert_eq!(y.len(), sys.data_dim());
    assert_eq!(wty.len(), m);
    let g = crate::coordinator::metrics::global();
    g.incr("solver.space.grid", 1);
    let ny = norm2(y);
    if ny == 0.0 {
        crate::coordinator::metrics::record_solver("refine", 0, true);
        return GridSolution {
            alpha: vec![0.0; sys.data_dim()],
            v: vec![0.0; m],
            iters: 0,
            rel_residual: 0.0,
            converged: true,
        };
    }
    let threshold = cfg.tol * sys.noise() * ny;
    let denom = sys.noise() * ny;
    let c = sys.rhs_from_wty(wty);
    let x0 = x0.filter(|x| x.len() == m);
    let seeded = x0.is_some();
    if seeded {
        g.incr("solver.warm.seeded", 1);
    }
    let (mut x, mut r) = match x0 {
        Some(x0) => {
            let gx = sys.apply_g(x0);
            let bx = sys.apply_b_given_g(x0, &gx);
            let r: Vec<f64> = c.iter().zip(&bx).map(|(ci, bi)| ci - bi).collect();
            (x0.to_vec(), r)
        }
        None => (vec![0.0; m], c.clone()),
    };
    let gr = sys.apply_g(&r);
    let mut rz = dot(&r, &gr).max(0.0);
    if rz.sqrt() <= threshold {
        // Same entry short-circuits as the f64 path: warm seeds inside
        // tolerance return bitwise; a zero-G-norm RHS solves exactly.
        if seeded {
            g.incr("solver.warm.hit", 1);
        } else if rz == 0.0 {
            for (xi, &ci) in x.iter_mut().zip(&c) {
                *xi = ci / sys.noise();
            }
        }
        crate::coordinator::metrics::record_solver("refine", 0, true);
        let alpha = sys.recover_alpha(y, &x);
        return GridSolution {
            alpha,
            v: x,
            iters: 0,
            rel_residual: rz.sqrt() / denom,
            converged: true,
        };
    }
    let f32v = GridSystemF32::new(sys);
    let mut inner_total = 0usize;
    let mut sweeps = 0usize;
    let mut converged = false;
    for _ in 0..MAX_OUTER {
        sweeps += 1;
        let (d, it) = inner_grid_cg_f32(&f32v, &r, cfg.max_iters);
        inner_total += it;
        axpy(1.0, &d, &mut x);
        // True f64 residual r = c − B x — the certificate only ever
        // consults f64 arithmetic.
        let gx = sys.apply_g(&x);
        let bx = sys.apply_b_given_g(&x, &gx);
        for ((ri, &ci), &bi) in r.iter_mut().zip(&c).zip(&bx) {
            *ri = ci - bi;
        }
        let gr = sys.apply_g(&r);
        let rz_new = dot(&r, &gr).max(0.0);
        if rz_new.sqrt() <= threshold {
            rz = rz_new;
            converged = true;
            break;
        }
        if !rz_new.is_finite() || rz_new.sqrt() > MIN_CONTRACTION * rz.sqrt() {
            g.incr("solver.refine.fallback.stall", 1);
            g.incr("solver.refine.sweeps", sweeps as u64);
            crate::coordinator::metrics::record_solver("refine", inner_total, false);
            let seed = if rz_new.is_finite() && rz_new < rz { Some(&x[..]) } else { x0 };
            return grid_cg_solve_f64(sys, y, wty, seed, cfg);
        }
        rz = rz_new;
    }
    if !converged {
        g.incr("solver.refine.fallback.sweep_budget", 1);
        g.incr("solver.refine.sweeps", sweeps as u64);
        crate::coordinator::metrics::record_solver("refine", inner_total, false);
        return grid_cg_solve_f64(sys, y, wty, Some(&x), cfg);
    }
    let rel = rz.sqrt() / denom;
    g.incr("solver.refine.sweeps", sweeps as u64);
    crate::coordinator::metrics::record_solver("refine", inner_total, true);
    let alpha = sys.recover_alpha(y, &x);
    GridSolution { alpha, v: x, iters: inner_total, rel_residual: rel, converged: true }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::ProductKernel;
    use crate::linalg::Matrix;
    use crate::operators::LinearOp;
    use crate::solvers::cg_solve;
    use crate::util::{rel_err, Rng};

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0))
    }

    /// Data-space covariance view of the same term set, for oracles.
    struct Cov {
        terms: Vec<(f64, Arc<KroneckerSkiOp>)>,
        sf2: f64,
        sn2: f64,
    }

    impl LinearOp for Cov {
        fn dim(&self) -> usize {
            self.terms[0].1.dim()
        }
        fn matvec(&self, v: &[f64]) -> Vec<f64> {
            let mut out = vec![0.0; v.len()];
            for (c, op) in &self.terms {
                for (o, x) in out.iter_mut().zip(op.matvec(v)) {
                    *o += c * x;
                }
            }
            for (o, &x) in out.iter_mut().zip(v) {
                *o = self.sf2 * *o + self.sn2 * x;
            }
            out
        }
    }

    fn dense_term(n: usize, seed: u64) -> (Matrix, Arc<KroneckerSkiOp>) {
        let xs = random_points(n, 2, seed);
        let kern = ProductKernel::rbf(2, 0.6, 1.0);
        let op = KroneckerSkiOp::new(&xs, &kern, 16).unwrap();
        (xs, Arc::new(op))
    }

    #[test]
    fn grid_solve_matches_data_space_cg() {
        let (_, op) = dense_term(90, 50);
        let (sf2, sn2) = (1.3, 0.25);
        let terms = vec![(1.0, op)];
        let cov = Cov { terms: terms.clone(), sf2, sn2 };
        let sys = GridSystem::new(terms, sf2, sn2).unwrap();
        let mut rng = Rng::new(51);
        let y = rng.normal_vec(90);
        let cfg = CgConfig { max_iters: 600, tol: 1e-10, ..CgConfig::default() };
        let data = cg_solve(&cov, &y, cfg);
        let grid = grid_cg_solve(&sys, &y, None, cfg);
        assert!(data.converged && grid.converged, "grid rel {}", grid.rel_residual);
        assert!(
            rel_err(&grid.alpha, &data.x) < 1e-7,
            "α drift {}",
            rel_err(&grid.alpha, &data.x)
        );
        // The recovered α really solves the covariance system: the
        // residual identity promises ‖K̂α − y‖ ≤ tol·‖y‖, same as data CG.
        let back = cov.matvec(&grid.alpha);
        assert!(rel_err(&back, &y) < 1e-9);
    }

    #[test]
    fn warm_seed_from_alpha_converges_immediately() {
        let (_, op) = dense_term(70, 52);
        let (sf2, sn2) = (1.0, 0.25);
        let sys = GridSystem::new(vec![(1.0, op)], sf2, sn2).unwrap();
        let mut rng = Rng::new(53);
        let y = rng.normal_vec(70);
        let tight = CgConfig { max_iters: 800, tol: 1e-10, ..CgConfig::default() };
        let cold = grid_cg_solve(&sys, &y, None, tight);
        assert!(cold.converged);
        // Seed with the grid iterate: bitwise return at 0 iterations.
        let loose = CgConfig { max_iters: 100, tol: 1e-6, ..CgConfig::default() };
        let warm = grid_cg_solve(&sys, &y, Some(&cold.v), loose);
        assert_eq!(warm.iters, 0);
        assert_eq!(warm.v, cold.v);
        // Seed translated from the data-space α is also near-converged.
        let seed = sys.seed_from_alpha(&cold.alpha);
        let warm2 = grid_cg_solve(&sys, &y, Some(&seed), loose);
        assert!(
            warm2.iters <= cold.iters / 2,
            "α-derived seed {} vs cold {}",
            warm2.iters,
            cold.iters
        );
        // A wrong-length seed is dropped, not panicked on.
        let bad = grid_cg_solve(&sys, &y, Some(&[1.0, 2.0]), loose);
        assert!(bad.converged);
    }

    #[test]
    fn multi_term_signed_combination_solves() {
        // A signed two-term system (combination-technique shape): K_big
        // is indefinite, but the G-inner-product iteration only sees the
        // PD covariance.
        let xs = random_points(60, 2, 54);
        let kern = ProductKernel::rbf(2, 0.7, 1.0);
        let fine = vec![
            crate::grid::Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let coarse = vec![
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let t1 = Arc::new(KroneckerSkiOp::with_grids(&xs, &kern, fine));
        let t2 = Arc::new(KroneckerSkiOp::with_grids(&xs, &kern, coarse));
        let terms = vec![(1.0, t1), (-0.3, t2)];
        let (sf2, sn2) = (1.0, 1.0);
        let cov = Cov { terms: terms.clone(), sf2, sn2 };
        let sys = GridSystem::new(terms, sf2, sn2).unwrap();
        let mut rng = Rng::new(55);
        let y = rng.normal_vec(60);
        let cfg = CgConfig { max_iters: 600, tol: 1e-10, ..CgConfig::default() };
        let grid = grid_cg_solve(&sys, &y, None, cfg);
        assert!(grid.converged, "rel {}", grid.rel_residual);
        // Dense Cholesky oracle (also certifies the covariance is PD, so
        // the G-weighted iteration was legitimately applicable).
        let dense = cov.to_dense();
        let want = crate::linalg::Cholesky::new(&dense).unwrap().solve(&y);
        assert!(
            rel_err(&grid.alpha, &want) < 1e-7,
            "{}",
            rel_err(&grid.alpha, &want)
        );
    }

    #[test]
    fn mixed_precision_grid_solve_meets_f64_certificate() {
        let (_, op) = dense_term(90, 57);
        let (sf2, sn2) = (1.3, 0.25);
        let terms = vec![(1.0, op)];
        let cov = Cov { terms: terms.clone(), sf2, sn2 };
        let sys = GridSystem::new(terms, sf2, sn2).unwrap();
        let mut rng = Rng::new(58);
        let y = rng.normal_vec(90);
        let cfg = CgConfig { max_iters: 600, tol: 1e-8, ..CgConfig::default() };
        let gold = grid_cg_solve(&sys, &y, None, cfg);
        let mixed = grid_cg_solve(
            &sys,
            &y,
            None,
            CgConfig { precision: Precision::Mixed, ..cfg },
        );
        assert!(gold.converged && mixed.converged, "rel {}", mixed.rel_residual);
        // Same certificate as f64 — and the recovered α agrees far
        // tighter than f32 storage alone could deliver.
        assert!(mixed.rel_residual <= 1e-8, "rel {}", mixed.rel_residual);
        assert!(
            rel_err(&mixed.alpha, &gold.alpha) < 1e-6,
            "α drift {}",
            rel_err(&mixed.alpha, &gold.alpha)
        );
        let back = cov.matvec(&mixed.alpha);
        assert!(rel_err(&back, &y) < 1e-7);
    }

    #[test]
    fn mixed_precision_multi_term_composition_path() {
        // Signed sparse-grid shape: the f32 G must run the Wᵀ(W u)
        // composition (no banded Gram for multi-term systems).
        let xs = random_points(60, 2, 59);
        let kern = ProductKernel::rbf(2, 0.7, 1.0);
        let fine = vec![
            crate::grid::Grid1d::fit(-1.0, 1.0, 12).unwrap(),
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let coarse = vec![
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
            crate::grid::Grid1d::fit_any(-1.0, 1.0, 3).unwrap(),
        ];
        let t1 = Arc::new(KroneckerSkiOp::with_grids(&xs, &kern, fine));
        let t2 = Arc::new(KroneckerSkiOp::with_grids(&xs, &kern, coarse));
        let terms = vec![(1.0, t1), (-0.3, t2)];
        let (sf2, sn2) = (1.0, 1.0);
        let sys = GridSystem::new(terms, sf2, sn2).unwrap();
        let mut rng = Rng::new(60);
        let y = rng.normal_vec(60);
        let cfg = CgConfig { max_iters: 600, tol: 1e-8, ..CgConfig::default() };
        let gold = grid_cg_solve(&sys, &y, None, cfg);
        let mixed = grid_cg_solve(
            &sys,
            &y,
            None,
            CgConfig { precision: Precision::Mixed, ..cfg },
        );
        assert!(gold.converged && mixed.converged, "rel {}", mixed.rel_residual);
        assert!(rel_err(&mixed.alpha, &gold.alpha) < 1e-6);
    }

    #[test]
    fn zero_rhs_and_zero_noise_guards() {
        let (_, op) = dense_term(30, 56);
        let sys = GridSystem::new(vec![(1.0, op.clone())], 1.0, 0.1).unwrap();
        let sol = grid_cg_solve(&sys, &vec![0.0; 30], None, CgConfig::default());
        assert!(sol.converged);
        assert!(sol.alpha.iter().all(|&a| a == 0.0));
        // σ_n² = 0 is a typed error, not a divide-by-zero at recover time.
        assert!(GridSystem::new(vec![(1.0, op)], 1.0, 0.0).is_err());
    }
}
