//! Stochastic Lanczos quadrature for log-determinants (paper §2.2;
//! Dong et al. 2017, Ubaru et al. 2017).
//!
//! For SPD `A`:  `log|A| = tr(log A) ≈ (n/p) Σ_z e₁ᵀ log(T_z) e₁ · ‖z‖²…`
//! more precisely, with Rademacher/Gaussian probes `z` and the Lanczos
//! tridiagonal `T_z` started from `z/‖z‖`:
//!
//! ```text
//! tr(f(A)) ≈ (1/p) Σ_z ‖z‖² Σ_i τ_i² f(θ_i)
//! ```
//!
//! where (θ_i, τ_i) are the eigenvalues of T_z and the first components of
//! its eigenvectors (Gauss quadrature nodes/weights).

use crate::linalg::tridiag::tridiag_eig;
use crate::linalg::Matrix;
use crate::operators::LinearOp;
use crate::solvers::lanczos::lanczos_batch;
use crate::util::Rng;

/// SLQ configuration.
#[derive(Clone, Copy, Debug)]
pub struct SlqConfig {
    /// Number of probe vectors.
    pub num_probes: usize,
    /// Lanczos steps per probe (quadrature order).
    pub max_rank: usize,
}

impl Default for SlqConfig {
    fn default() -> Self {
        SlqConfig { num_probes: 10, max_rank: 25 }
    }
}

/// Estimate `tr(f(A))` for SPD operator `A`.
///
/// All probes run through the batched Lanczos path
/// ([`lanczos_batch`]): the probe block is drawn up front (same RNG
/// stream as the historical one-probe-at-a-time loop, so estimates are
/// seed-compatible) and every quadrature iteration costs one fused
/// [`LinearOp::matmat`] over the still-active probes instead of
/// `num_probes` independent operator traversals.
pub fn slq_trace_fn(
    a: &dyn LinearOp,
    f: impl Fn(f64) -> f64,
    cfg: SlqConfig,
    rng: &mut Rng,
) -> f64 {
    let n = a.dim();
    let mut probes = Matrix::zeros(n, cfg.num_probes);
    for j in 0..cfg.num_probes {
        probes.set_col(j, &rng.rademacher_vec(n));
    }
    let results = lanczos_batch(a, &probes, cfg.max_rank, 1e-10);
    let mut acc = 0.0;
    for res in &results {
        let z_norm_sq = n as f64; // ‖z‖² = n for Rademacher probes.
        let eig = tridiag_eig(&res.alphas, &res.betas)
            .expect("SLQ: tridiagonal eigensolver failed");
        let quad: f64 = eig
            .eigenvalues
            .iter()
            .zip(&eig.first_components)
            .map(|(&theta, &tau)| {
                // Clamp tiny/negative Ritz values (roundoff on PSD input).
                let theta = theta.max(1e-12);
                tau * tau * f(theta)
            })
            .sum();
        acc += z_norm_sq * quad;
    }
    acc / cfg.num_probes as f64
}

/// Estimate `log|A|` for SPD `A`.
pub fn slq_logdet(a: &dyn LinearOp, cfg: SlqConfig, rng: &mut Rng) -> f64 {
    slq_trace_fn(a, |x| x.ln(), cfg, rng)
}

/// Hutchinson estimate of `tr(A⁻¹ B)` given a solver for `A` and MVMs with
/// `B` — the trace term in MLL gradients: `dL/dθ` needs `tr(K̂⁻¹ ∂K/∂θ)`.
pub fn hutchinson_trace_inv_prod(
    solve_a: impl Fn(&[f64]) -> Vec<f64>,
    b: &dyn LinearOp,
    num_probes: usize,
    rng: &mut Rng,
) -> f64 {
    let n = b.dim();
    let mut acc = 0.0;
    for _ in 0..num_probes {
        let z = rng.rademacher_vec(n);
        let bz = b.matvec(&z);
        let ainv_bz = solve_a(&bz);
        acc += z.iter().zip(&ainv_bz).map(|(a, b)| a * b).sum::<f64>();
    }
    acc / num_probes as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::{DenseOp, DiagOp};
    use crate::solvers::cg::{cg_solve, CgConfig};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.1);
        a
    }

    #[test]
    fn logdet_of_diagonal_exact() {
        let d = vec![1.0, 2.0, 4.0, 8.0];
        let op = DiagOp(d.clone());
        let mut rng = Rng::new(1);
        // Full-rank quadrature on a diagonal matrix is exact in expectation;
        // with enough probes the estimate is tight.
        let cfg = SlqConfig { num_probes: 300, max_rank: 4 };
        let got = slq_logdet(&op, cfg, &mut rng);
        let want: f64 = d.iter().map(|x| x.ln()).sum();
        assert!((got - want).abs() < 0.15 * want.abs().max(1.0), "{got} vs {want}");
    }

    #[test]
    fn logdet_matches_cholesky() {
        let n = 40;
        let dense = random_spd(n, 2);
        let want = Cholesky::new(&dense).unwrap().logdet();
        let op = DenseOp(dense);
        let mut rng = Rng::new(3);
        let cfg = SlqConfig { num_probes: 60, max_rank: 40 };
        let got = slq_logdet(&op, cfg, &mut rng);
        let rel = (got - want).abs() / want.abs();
        assert!(rel < 0.05, "slq {got} vs chol {want} (rel {rel})");
    }

    #[test]
    fn trace_of_identity_function() {
        // f(x) = x ⇒ tr(A).
        let dense = random_spd(25, 4);
        let want = dense.trace();
        let op = DenseOp(dense);
        let mut rng = Rng::new(5);
        let cfg = SlqConfig { num_probes: 100, max_rank: 25 };
        let got = slq_trace_fn(&op, |x| x, cfg, &mut rng);
        assert!((got - want).abs() / want.abs() < 0.05, "{got} vs {want}");
    }

    #[test]
    fn hutchinson_trace_inv() {
        // tr(A⁻¹ B) against dense computation.
        let a_dense = random_spd(20, 6);
        let b_dense = random_spd(20, 7);
        let chol = Cholesky::new(&a_dense).unwrap();
        let want = chol.solve_mat(&b_dense).trace();
        let a_op = DenseOp(a_dense);
        let b_op = DenseOp(b_dense);
        let mut rng = Rng::new(8);
        let got = hutchinson_trace_inv_prod(
            |v| cg_solve(&a_op, v, CgConfig::default()).x,
            &b_op,
            200,
            &mut rng,
        );
        assert!((got - want).abs() / want.abs() < 0.1, "{got} vs {want}");
    }

    #[test]
    fn deterministic_given_seed() {
        let dense = random_spd(15, 9);
        let op = DenseOp(dense);
        let cfg = SlqConfig { num_probes: 5, max_rank: 10 };
        let a = slq_logdet(&op, cfg, &mut Rng::new(42));
        let b = slq_logdet(&op, cfg, &mut Rng::new(42));
        assert_eq!(a, b);
    }
}
