//! Iterative Krylov solvers: CG (single and block multi-RHS), Lanczos
//! (single and batched-probe), stochastic Lanczos quadrature.

pub mod block_cg;
pub mod cg;
pub mod lanczos;
pub mod slq;

pub use block_cg::{block_cg_solve, BlockCgColumn, BlockCgSolution};
pub use cg::{cg_solve, cg_solve_many, CgConfig, CgSolution};
pub use lanczos::{lanczos, lanczos_batch, LanczosResult};
pub use slq::{hutchinson_trace_inv_prod, slq_logdet, slq_trace_fn, SlqConfig};
