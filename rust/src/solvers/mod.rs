//! Iterative Krylov solvers: CG, Lanczos, stochastic Lanczos quadrature.

pub mod cg;
pub mod lanczos;
pub mod slq;

pub use cg::{cg_solve, cg_solve_many, CgConfig, CgSolution};
pub use lanczos::{lanczos, LanczosResult};
pub use slq::{hutchinson_trace_inv_prod, slq_logdet, slq_trace_fn, SlqConfig};
