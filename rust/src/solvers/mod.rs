//! Iterative Krylov solvers: preconditioned CG (single and block
//! multi-RHS, with warm starts), Lanczos (single and batched-probe),
//! stochastic Lanczos quadrature — plus the preconditioners themselves
//! ([`precond`]: identity / Jacobi / partial pivoted Cholesky), the
//! grid-space normal-equations engine ([`gridspace`]), whose per-iteration
//! cost is independent of n, and the mixed-precision refinement wrapper
//! ([`refine`]) that runs the hot MVMs in f32 under an f64 outer loop.
//! The four deployment-facing knobs (preconditioner, precision, solve
//! space, warm starts) are bundled by [`policy::SolverPolicy`], the one
//! struct every embedding config (training, streaming, snapshots) and
//! the CLI share.
//!
//! Tuning the solvers (tolerance vs. preconditioner rank vs. warm
//! starts, and how to read the p50/p99 solver-effort summary lines) is
//! covered in `docs/SOLVERS.md` at the repository root.

pub mod block_cg;
pub mod cg;
pub mod gridspace;
pub mod lanczos;
pub mod policy;
pub mod precond;
pub mod refine;
pub mod slq;

pub use block_cg::{block_cg_solve, block_cg_solve_with, BlockCgColumn, BlockCgSolution};
pub use cg::{cg_solve, cg_solve_many, cg_solve_with, CgConfig, CgSolution};
pub use gridspace::{
    grid_cg_solve, grid_cg_solve_with_wty, GridSolution, GridSystem,
};
pub use lanczos::{lanczos, lanczos_batch, LanczosResult};
pub use policy::{SolveSpace, SolverPolicy};
pub use precond::{
    build_preconditioner, IdentityPrecond, JacobiPrecond, PaddedPrecond,
    PivotedCholeskyPrecond, PrecondCost, PrecondSpec, Preconditioner,
};
pub use refine::{raw_cg_f32, refined_cg_solve, Precision};
pub use slq::{hutchinson_trace_inv_prod, slq_logdet, slq_trace_fn, SlqConfig};
