//! The solver policy shared by every layer that issues covariance
//! solves.
//!
//! Training ([`crate::gp::MvmGpConfig`]), streaming ingest
//! ([`crate::stream::StreamConfig`]), and snapshot building
//! ([`crate::serve::SnapshotConfig`]) all answer the same four
//! questions before touching a Krylov solver: which preconditioner,
//! which arithmetic, which space, and whether successive solves may
//! seed from the previous solution. [`SolverPolicy`] bundles those
//! answers in one struct so the configs embed *one* policy instead of
//! re-declaring the knobs — and [`SolverPolicy::from_cli`] is the one
//! place the `--precond` / `--space` / `--precision` flags are parsed,
//! with the exact error wordings the CLI has always produced.
//!
//! None of the knobs changes *what* a solve converges to — the
//! preconditioner and warm start change where CG starts and how fast it
//! contracts, mixed precision meets the same residual certificate
//! through iterative refinement, and both solve spaces share one
//! tolerance contract (see [`SolveSpace`]). A policy is therefore
//! always safe to tune per deployment.

use super::precond::PrecondSpec;
use super::refine::Precision;
use crate::Result;

/// Which space the covariance y-solves run in (Yadav, Sheldon & Musco
/// 2021 — see `crate::solvers::gridspace` for the derivation and
/// `docs/SOLVERS.md` for the decision table).
///
/// Both spaces converge on the *same* certificate
/// (`‖K̂α − y‖ ≤ tol·‖y‖`), so switching spaces changes iteration cost,
/// never the answer beyond the tolerance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveSpace {
    /// Grid space for KISS models when the grid admits it (the `WᵀW`
    /// band fits its budget, axes are non-degenerate), data space
    /// otherwise — the default.
    Auto,
    /// Always solve in data space (n-dimensional CG/PCG) — the
    /// equivalence oracle the grid path is tested against.
    Data,
    /// Always solve in grid space. A typed [`crate::Error::Config`] for
    /// the SKIP variant (no tensor-product `W` to project through) and a
    /// typed [`crate::Error::Grid`] when the grid refuses (over-budget
    /// band, degenerate axes).
    Grid,
}

/// How this deployment wants its covariance solves run — embedded by
/// [`crate::gp::MvmGpConfig`], [`crate::stream::StreamConfig`], and
/// [`crate::serve::SnapshotConfig`] so the four knobs are declared (and
/// CLI-parsed) exactly once.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolverPolicy {
    /// Preconditioner for the data-space solves (`--precond
    /// rank:K|jacobi|none`), built once per operator with the exact
    /// noise shift σ_n². Folded into [`super::CgConfig::precond`] at
    /// model/state construction whenever it is not
    /// [`PrecondSpec::None`] — a caller that set `cg.precond` directly
    /// keeps their choice under the default policy.
    pub precond: PrecondSpec,
    /// Arithmetic for the solves (`--precision f64|mixed`):
    /// [`Precision::F64`] runs classic double-precision PCG;
    /// [`Precision::Mixed`] runs the hot MVMs in f32 inside an f64
    /// iterative-refinement loop that meets the same residual
    /// certificate (see `crate::solvers::refine`). Folded into
    /// [`super::CgConfig::precision`] the same way — Mixed only ever
    /// *adds*.
    pub precision: Precision,
    /// Which space the covariance y-solves run in (`--space
    /// auto|data|grid`).
    pub space: SolveSpace,
    /// Warm-start successive iterative solves with the previous
    /// solution. Warm starts change where CG *starts*, never what it
    /// converges to; disable for bit-reproducibility of individual
    /// solves against cold runs.
    pub warm_start: bool,
}

impl Default for SolverPolicy {
    fn default() -> Self {
        SolverPolicy {
            precond: PrecondSpec::None,
            precision: Precision::F64,
            space: SolveSpace::Auto,
            warm_start: true,
        }
    }
}

impl SolverPolicy {
    /// Parse the three solver CLI flags — the values of `--precond`,
    /// `--space`, and `--precision`, each `None` when absent — into a
    /// policy. This is the *only* parser for these flags; every
    /// subcommand (`train`, `snapshot`, `serve --live`, benches) calls
    /// it, so the accepted grammar and the error wordings cannot drift
    /// between entrypoints.
    pub fn from_cli(
        precond: Option<&str>,
        space: Option<&str>,
        precision: Option<&str>,
    ) -> Result<Self> {
        let precond = PrecondSpec::parse(precond.unwrap_or("none"))?;
        let space = match space {
            None | Some("auto") => SolveSpace::Auto,
            Some("data") => SolveSpace::Data,
            Some("grid") => SolveSpace::Grid,
            Some(v) => {
                return Err(crate::Error::Config(format!(
                    "bad value for --space: '{v}' (auto|data|grid)"
                )))
            }
        };
        let precision = match precision {
            None => Precision::F64,
            Some(v) => Precision::parse(v).ok_or_else(|| {
                crate::Error::Config(format!(
                    "bad value for --precision: '{v}' (f64|mixed)"
                ))
            })?,
        };
        Ok(SolverPolicy {
            precond,
            precision,
            space,
            ..SolverPolicy::default()
        })
    }

    /// Fold this policy into a [`super::CgConfig`] — the shared
    /// "policy only ever adds" rule every embedding config applies at
    /// construction: a non-default policy knob overrides the CG config,
    /// a default one keeps whatever the caller set on `cg` directly.
    pub fn fold_into(&self, cg: &mut super::CgConfig) {
        if self.precision == Precision::Mixed {
            cg.precision = Precision::Mixed;
        }
        if !matches!(self.precond, PrecondSpec::None) {
            cg.precond = self.precond;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_the_historical_default() {
        let p = SolverPolicy::default();
        assert!(matches!(p.precond, PrecondSpec::None));
        assert_eq!(p.precision, Precision::F64);
        assert_eq!(p.space, SolveSpace::Auto);
        assert!(p.warm_start);
    }

    #[test]
    fn cli_parser_accepts_the_full_grammar() {
        let p = SolverPolicy::from_cli(Some("rank:20"), Some("grid"), Some("mixed"))
            .unwrap();
        assert!(matches!(p.precond, PrecondSpec::PivChol { rank: 20 }));
        assert_eq!(p.space, SolveSpace::Grid);
        assert_eq!(p.precision, Precision::Mixed);
        let p = SolverPolicy::from_cli(None, None, None).unwrap();
        assert_eq!(p, SolverPolicy::default());
    }

    #[test]
    fn cli_parser_preserves_legacy_error_wordings() {
        let e = SolverPolicy::from_cli(None, Some("gird"), None).unwrap_err();
        assert_eq!(
            e.to_string(),
            "config error: bad value for --space: 'gird' (auto|data|grid)"
        );
        let e = SolverPolicy::from_cli(None, None, Some("half")).unwrap_err();
        assert_eq!(
            e.to_string(),
            "config error: bad value for --precision: 'half' (f64|mixed)"
        );
        let e = SolverPolicy::from_cli(Some("rank:0"), None, None).unwrap_err();
        assert_eq!(
            e.to_string(),
            "config error: bad --precond 'rank:0' (expected rank:K, jacobi, or none)"
        );
    }

    #[test]
    fn fold_only_ever_adds() {
        let mut cg = super::super::CgConfig {
            precond: PrecondSpec::Jacobi,
            ..Default::default()
        };
        SolverPolicy::default().fold_into(&mut cg);
        assert!(matches!(cg.precond, PrecondSpec::Jacobi));
        assert_eq!(cg.precision, Precision::F64);
        let pol = SolverPolicy {
            precond: PrecondSpec::PivChol { rank: 5 },
            precision: Precision::Mixed,
            ..Default::default()
        };
        pol.fold_into(&mut cg);
        assert!(matches!(cg.precond, PrecondSpec::PivChol { rank: 5 }));
        assert_eq!(cg.precision, Precision::Mixed);
    }
}
