//! Preconditioners for the iterative solvers (Yadav, Sheldon & Musco
//! 2021; Gardner et al. 2018's GPyTorch practice).
//!
//! CG's iteration count grows with √κ(K̂), and κ explodes exactly where
//! GP inference wants to operate: small noise σ_n² under a fast-decaying
//! kernel spectrum. A **low-rank-plus-diagonal** preconditioner
//! `M = L_k L_kᵀ + δ I` built from k adaptively-pivoted columns of the
//! operator captures the dominant spectrum, so `M⁻¹K̂` has its large
//! eigenvalues collapsed to ≈1 and PCG converges in a near-constant
//! handful of iterations — with **zero accuracy change** (the solution of
//! the preconditioned system is the solution of the original one).
//!
//! Three implementations of the [`Preconditioner`] trait:
//!
//! - [`IdentityPrecond`] — no-op; [`cg_solve`] with the identity runs the
//!   bitwise-identical recurrence the unpreconditioned solver always ran.
//! - [`JacobiPrecond`] — `M = diag(K̂)`; one elementwise multiply per
//!   application. Useful when the diagonal varies (multi-task / sum
//!   operators); a stationary kernel's constant diagonal makes it a no-op
//!   up to scaling.
//! - [`PivotedCholeskyPrecond`] — partial pivoted Cholesky
//!   `L_k L_kᵀ ≈ K` from k greedily-chosen columns (largest residual
//!   diagonal first), applied via the Woodbury identity in O(nk) per
//!   vector. Setup costs k operator columns = k MVMs (cheap against the
//!   structured operators' O(n + m log m) columns) plus the diagonal
//!   accessor [`LinearOp::diag`] for adaptive pivot selection.
//!
//! Which to use: see `docs/SOLVERS.md` for the tuning guide; the short
//! version is `rank:50` for ill-conditioned (small-σ_n²) solves, `none`
//! for well-conditioned ones where k setup MVMs would never pay for
//! themselves.
//!
//! ```
//! use skip_gp::linalg::Matrix;
//! use skip_gp::operators::DenseOp;
//! use skip_gp::solvers::{build_preconditioner, Preconditioner, PrecondSpec};
//!
//! let a = DenseOp(Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]));
//! let m = build_preconditioner(&a, None, PrecondSpec::PivChol { rank: 2 });
//! // A full-rank pivoted Cholesky inverts A (up to the diagonal floor):
//! let z = m.apply(&[4.0, 1.0]);
//! assert!((z[0] - 1.0).abs() < 1e-6 && z[1].abs() < 1e-6);
//! assert_eq!(m.cost().rank, 2);
//! ```
//!
//! [`cg_solve`]: super::cg::cg_solve

use crate::linalg::{Cholesky, Matrix};
use crate::operators::LinearOp;
use crate::{Error, Result};

/// Which preconditioner to build for a solve — the serializable,
/// `Copy`-able *specification* threaded through [`super::CgConfig`],
/// `MvmGpConfig`, `SnapshotConfig`, and the `skip-gp` CLI
/// (`--precond rank:K|jacobi|none`). The concrete [`Preconditioner`] is
/// constructed per operator by [`build_preconditioner`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum PrecondSpec {
    /// Unpreconditioned CG (the identity preconditioner).
    #[default]
    None,
    /// Diagonal (Jacobi) scaling from [`LinearOp::diag`]; falls back to
    /// the identity when the operator has no cheap diagonal.
    Jacobi,
    /// Partial pivoted Cholesky of rank ≤ `rank`, Woodbury-applied.
    PivChol { rank: usize },
}

impl PrecondSpec {
    /// Parse the CLI syntax: `"none"`, `"jacobi"`, or `"rank:K"`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(PrecondSpec::None),
            "jacobi" => Ok(PrecondSpec::Jacobi),
            _ => match s.strip_prefix("rank:").and_then(|k| k.parse::<usize>().ok()) {
                Some(rank) if rank > 0 => Ok(PrecondSpec::PivChol { rank }),
                _ => Err(Error::Config(format!(
                    "bad --precond '{s}' (expected rank:K, jacobi, or none)"
                ))),
            },
        }
    }

    /// Human-readable form (round-trips through [`PrecondSpec::parse`]).
    pub fn describe(&self) -> String {
        match self {
            PrecondSpec::None => "none".to_string(),
            PrecondSpec::Jacobi => "jacobi".to_string(),
            PrecondSpec::PivChol { rank } => format!("rank:{rank}"),
        }
    }

    /// True for [`PrecondSpec::None`].
    pub fn is_none(&self) -> bool {
        matches!(self, PrecondSpec::None)
    }
}

/// Setup-cost report of a built preconditioner, so callers can weigh the
/// construction against the iterations it is expected to save (a rank-k
/// setup pays for itself once it removes ≥ k CG iterations: both are one
/// operator MVM each).
#[derive(Clone, Copy, Debug, Default)]
pub struct PrecondCost {
    /// Operator MVMs consumed during setup (column sampling).
    pub setup_matvecs: usize,
    /// Rank of the low-rank factor (0 for identity/Jacobi).
    pub rank: usize,
    /// Approximate flops per [`Preconditioner::apply`] call.
    pub apply_flops: usize,
}

/// A symmetric-positive-definite approximation `M ≈ K̂` whose inverse is
/// cheap to apply. Implementations must be deterministic: CG calls
/// [`apply`](Preconditioner::apply) every iteration and the recurrence
/// assumes a fixed `M`.
pub trait Preconditioner: Send + Sync {
    /// Operator dimension n.
    fn dim(&self) -> usize;

    /// `z = M⁻¹ r`.
    fn apply(&self, r: &[f64]) -> Vec<f64>;

    /// `Z = M⁻¹ R` for an n×t block. The default falls back to
    /// column-by-column [`apply`](Preconditioner::apply); implementations
    /// with a blocked fast path (Woodbury via three gemms) override it —
    /// block-PCG calls this once per iteration for all active columns.
    fn apply_block(&self, r: &Matrix) -> Matrix {
        assert_eq!(r.rows, self.dim());
        let mut out = Matrix::zeros(r.rows, r.cols);
        for j in 0..r.cols {
            out.set_col(j, &self.apply(&r.col(j)));
        }
        out
    }

    /// What this preconditioner cost to build and costs to apply.
    fn cost(&self) -> PrecondCost;

    /// Short name for metrics/logs (`"identity"`, `"jacobi"`,
    /// `"pivchol"`).
    fn name(&self) -> &'static str;
}

/// The no-op preconditioner: `M = I`.
pub struct IdentityPrecond {
    n: usize,
}

impl IdentityPrecond {
    pub fn new(n: usize) -> Self {
        IdentityPrecond { n }
    }
}

impl Preconditioner for IdentityPrecond {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        r.to_vec()
    }

    fn apply_block(&self, r: &Matrix) -> Matrix {
        assert_eq!(r.rows, self.n);
        r.clone()
    }

    fn cost(&self) -> PrecondCost {
        PrecondCost::default()
    }

    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Diagonal (Jacobi) preconditioner `M = diag(K̂)`.
pub struct JacobiPrecond {
    inv_diag: Vec<f64>,
}

impl JacobiPrecond {
    /// Build from an explicit diagonal; every entry must be positive (K̂
    /// is SPD, so a non-positive diagonal entry means the operator — or
    /// its [`LinearOp::diag`] override — is broken).
    pub fn new(diag: Vec<f64>) -> Result<Self> {
        if diag.iter().any(|&d| d <= 0.0 || !d.is_finite()) {
            return Err(Error::Config(
                "Jacobi preconditioner needs a strictly positive diagonal".into(),
            ));
        }
        Ok(JacobiPrecond { inv_diag: diag.iter().map(|d| 1.0 / d).collect() })
    }

    /// Build from an operator's diagonal accessor (None when the operator
    /// has no cheap diagonal or it is not strictly positive).
    pub fn from_op(op: &dyn LinearOp) -> Option<Self> {
        Self::new(op.diag()?).ok()
    }
}

impl Preconditioner for JacobiPrecond {
    fn dim(&self) -> usize {
        self.inv_diag.len()
    }

    fn apply(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.inv_diag.len());
        r.iter().zip(&self.inv_diag).map(|(x, d)| x * d).collect()
    }

    fn apply_block(&self, r: &Matrix) -> Matrix {
        assert_eq!(r.rows, self.inv_diag.len());
        let mut out = r.clone();
        for (i, &d) in self.inv_diag.iter().enumerate() {
            for v in out.row_mut(i) {
                *v *= d;
            }
        }
        out
    }

    fn cost(&self) -> PrecondCost {
        PrecondCost { setup_matvecs: 0, rank: 0, apply_flops: self.inv_diag.len() }
    }

    fn name(&self) -> &'static str {
        "jacobi"
    }
}

/// Partial pivoted-Cholesky preconditioner `M = L_k L_kᵀ + δ I`,
/// Woodbury-applied in O(nk) per vector.
///
/// Setup runs the greedy partial pivoted Cholesky of the kernel part of
/// `K̂`: at each of k steps it picks the index with the largest residual
/// diagonal (or, for operators with no cheap [`LinearOp::diag`], an
/// evenly-spread deterministic pivot — every column is normalized by its
/// exact residual diagonal read off the fetched column, so the factor
/// stays a valid partial Cholesky either way), fetches that operator
/// column ([`LinearOp::col_at`], one MVM), orthogonalizes it against the
/// factor so far, and downdates the residual diagonal.
///
/// With a `noise_hint` (the caller knows σ_n², as the GP layer does) the
/// shift is removed from the sampled columns and
/// `δ = σ_n²` exactly; without one the factorization runs on `K̂` itself
/// and `δ` self-calibrates to the mean residual diagonal — the leftover
/// spectral mass the factor did not capture, which for a noise-shifted
/// covariance converges onto σ_n² as k grows.
///
/// Application uses the Woodbury identity with the k×k Gram factor cached
/// at build time:
///
/// ```text
/// M⁻¹ r = (r − L G⁻¹ Lᵀ r) / δ,   G = δ I_k + Lᵀ L   (Cholesky, cached)
/// ```
///
/// The block form ([`Preconditioner::apply_block`]) is three gemms and is
/// what block-PCG drives once per iteration.
pub struct PivotedCholeskyPrecond {
    /// n×k factor (k ≤ requested rank; the build stops early when the
    /// residual diagonal is exhausted).
    l: Matrix,
    /// Diagonal floor δ: σ_n² when hinted; else the mean residual
    /// diagonal, or the last pivot's residual level when the operator has
    /// no diagonal to read.
    noise: f64,
    /// Cholesky of `G = δ I_k + LᵀL`.
    small: Cholesky,
    /// Pivot indices in selection order (diagnostics / tests).
    pub pivots: Vec<usize>,
    setup_matvecs: usize,
}

impl PivotedCholeskyPrecond {
    /// Build a rank ≤ `rank` preconditioner for `op` (the full,
    /// noise-shifted K̂). `noise_hint` is the additive diagonal shift
    /// σ_n² when the caller knows it (see the type docs for how the
    /// build self-calibrates without it).
    pub fn build(op: &dyn LinearOp, rank: usize, noise_hint: Option<f64>) -> Result<Self> {
        let n = op.dim();
        if n == 0 {
            return Err(Error::Config("pivoted Cholesky of an empty operator".into()));
        }
        let shift = noise_hint.unwrap_or(0.0);
        // Residual diagonal of the kernel part, when the operator can
        // produce it cheaply — it drives the *greedy* pivot choice.
        // Without it, pivots fall back to an evenly-spread deterministic
        // sequence; either way every column is normalized by its **exact**
        // residual diagonal read off the fetched column itself, so the
        // factorization is a valid partial Cholesky regardless (pivot
        // adaptivity only affects which columns it spends the budget on).
        let mut d: Option<Vec<f64>> = op
            .diag()
            .map(|diag| diag.into_iter().map(|v| v - shift).collect());
        // Scale reference for the stop/floor thresholds: the largest
        // (residual) diagonal seen so far.
        let mut seen_max = d
            .as_ref()
            .map(|d| d.iter().cloned().fold(0.0f64, f64::max))
            .unwrap_or(0.0);
        let k_max = rank.min(n);
        let stride = (n / k_max.max(1)).max(1);
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(k_max);
        let mut pivots: Vec<usize> = Vec::with_capacity(k_max);
        let mut matvecs = 0usize;
        // Residual level of the last accepted pivot — the δ estimate when
        // neither a noise hint nor a residual diagonal is available.
        let mut last_dp = 0.0f64;
        for step in 0..k_max {
            let p = match &d {
                Some(d) => {
                    let (p, dp) = d
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, &v)| (i, v))
                        .expect("non-empty diagonal");
                    if dp <= 1e-12 * seen_max.max(1.0) {
                        break; // residual exhausted — the factor is complete
                    }
                    p
                }
                None => (step * stride) % n,
            };
            let mut col = op.col_at(p);
            matvecs += 1;
            col[p] -= shift;
            // Orthogonalize against the factor so far:
            // l = (a_p − L L[p,·]ᵀ) / √d_p.
            for prev in &cols {
                let lp = prev[p];
                for (c, &v) in col.iter_mut().zip(prev) {
                    *c -= lp * v;
                }
            }
            // The exact residual diagonal at p: a_pp − Σ_j L[p,j]², i.e.
            // this column's own pivot entry after orthogonalization.
            let dp = col[p];
            seen_max = seen_max.max(dp);
            if dp <= 1e-12 * seen_max.max(1.0) {
                // Pivot numerically exhausted. Under greedy selection this
                // was the *largest* residual, so the factor is complete;
                // a spread (diag-less) pivot says nothing about the other
                // candidates — skip it and keep spending the budget.
                if d.is_some() {
                    break;
                }
                continue;
            }
            last_dp = dp;
            let scale = 1.0 / dp.sqrt();
            for c in col.iter_mut() {
                *c *= scale;
            }
            if let Some(d) = &mut d {
                for (di, &ci) in d.iter_mut().zip(&col) {
                    *di = (*di - ci * ci).max(0.0);
                }
                d[p] = 0.0;
            }
            pivots.push(p);
            cols.push(col);
        }
        let k = cols.len();
        let mut l = Matrix::zeros(n, k);
        for (j, c) in cols.iter().enumerate() {
            l.set_col(j, c);
        }
        // Diagonal floor: the known σ_n², else the mean residual diagonal,
        // else (no diagonal to read) the residual level of the last
        // accepted pivot — a δ too *small* is the dangerous direction (it
        // blows up M⁻¹ on the uncaptured complement and can make PCG
        // slower than plain CG), while the last-pivot overestimate only
        // degrades gently. The clamp keeps the Woodbury division finite
        // AND dominates the cancellation error of its numerator
        // (≈ machine-ε·‖r‖), which a floor near ε would amplify to O(1).
        let resid_estimate = d
            .as_ref()
            .map(|d| d.iter().sum::<f64>() / n as f64)
            .unwrap_or(last_dp);
        let noise = noise_hint
            .unwrap_or(resid_estimate)
            .max(1e-8 * seen_max.max(1.0));
        let mut g = l.t_matmul(&l);
        g.add_diag(noise);
        let small = Cholesky::new_with_jitter(&g, 0.0)?;
        crate::coordinator::metrics::global()
            .observe("solver.precond.setup_matvecs", matvecs as u64);
        Ok(PivotedCholeskyPrecond { l, noise, small, pivots, setup_matvecs: matvecs })
    }

    /// Achieved rank k (≤ the requested rank).
    pub fn rank(&self) -> usize {
        self.l.cols
    }

    /// The diagonal floor δ in `M = L Lᵀ + δ I`.
    pub fn noise(&self) -> f64 {
        self.noise
    }
}

impl Preconditioner for PivotedCholeskyPrecond {
    fn dim(&self) -> usize {
        self.l.rows
    }

    fn apply(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.l.rows);
        if self.l.cols == 0 {
            return r.iter().map(|v| v / self.noise).collect();
        }
        let t = self.l.t_matvec(r); // Lᵀ r, k
        let u = self.small.solve(&t); // G⁻¹ Lᵀ r, k
        let lu = self.l.matvec(&u); // L G⁻¹ Lᵀ r, n
        r.iter()
            .zip(&lu)
            .map(|(ri, li)| (ri - li) / self.noise)
            .collect()
    }

    /// Blocked Woodbury: `(R − L G⁻¹ (Lᵀ R)) / δ` — three gemms for the
    /// whole block instead of t gemv chains.
    fn apply_block(&self, r: &Matrix) -> Matrix {
        assert_eq!(r.rows, self.l.rows);
        if self.l.cols == 0 {
            let mut out = r.clone();
            for v in out.data.iter_mut() {
                *v /= self.noise;
            }
            return out;
        }
        let t = self.l.t_matmul(r); // k×t
        let u = self.small.solve_mat(&t); // k×t
        let lu = self.l.matmul(&u); // n×t
        let mut out = r.clone();
        for (o, &x) in out.data.iter_mut().zip(&lu.data) {
            *o = (*o - x) / self.noise;
        }
        out
    }

    fn cost(&self) -> PrecondCost {
        let (n, k) = (self.l.rows, self.l.cols);
        PrecondCost {
            setup_matvecs: self.setup_matvecs,
            rank: k,
            // Two n×k gemvs + one k×k triangular solve pair.
            apply_flops: 4 * n * k + 2 * k * k,
        }
    }

    fn name(&self) -> &'static str {
        "pivchol"
    }
}

/// A preconditioner for a *grown* system: the cached `inner` (built for
/// the leading `inner.dim()` rows of an operator that has since gained
/// rows) applied block-diagonally with a Jacobi tail for the new rows,
///
/// ```text
/// M = [ M_inner      0      ]          z[..n₀] = M_inner⁻¹ r[..n₀]
///     [    0     tail_diag·I ],        z[n₀..] = r[n₀..] / tail_diag
/// ```
///
/// which is SPD whenever `inner` is and `tail_diag > 0`. This is how the
/// streaming path ([`crate::stream`]) reuses an expensive rank-k setup
/// across ingests while the hyperparameters are unchanged: appended
/// observations only see the exact covariance diagonal σ_f² + σ_n² (the
/// natural `tail_diag` for an RBF K̂) until the next full refresh rebuilds
/// the preconditioner at full size.
pub struct PaddedPrecond<'a> {
    inner: &'a dyn Preconditioner,
    tail_diag: f64,
    n: usize,
}

impl<'a> PaddedPrecond<'a> {
    /// Pad `inner` out to dimension `n ≥ inner.dim()` with a constant
    /// Jacobi tail of `tail_diag` (> 0, typically the operator's exact
    /// diagonal value for the appended rows).
    pub fn new(inner: &'a dyn Preconditioner, n: usize, tail_diag: f64) -> Self {
        assert!(n >= inner.dim(), "padded dim must not shrink the inner");
        assert!(
            tail_diag.is_finite() && tail_diag > 0.0,
            "tail diagonal must be positive (got {tail_diag})"
        );
        PaddedPrecond { inner, tail_diag, n }
    }
}

impl Preconditioner for PaddedPrecond<'_> {
    fn dim(&self) -> usize {
        self.n
    }

    fn apply(&self, r: &[f64]) -> Vec<f64> {
        assert_eq!(r.len(), self.n);
        let n0 = self.inner.dim();
        let mut z = self.inner.apply(&r[..n0]);
        z.extend(r[n0..].iter().map(|x| x / self.tail_diag));
        z
    }

    fn apply_block(&self, r: &Matrix) -> Matrix {
        assert_eq!(r.rows, self.n);
        let n0 = self.inner.dim();
        let top = Matrix {
            rows: n0,
            cols: r.cols,
            data: r.data[..n0 * r.cols].to_vec(),
        };
        let mut out = self.inner.apply_block(&top);
        out.rows = self.n;
        out.data
            .extend(r.data[n0 * r.cols..].iter().map(|x| x / self.tail_diag));
        out
    }

    fn cost(&self) -> PrecondCost {
        let inner = self.inner.cost();
        PrecondCost {
            setup_matvecs: 0, // the padding itself costs nothing to set up
            rank: inner.rank,
            apply_flops: inner.apply_flops + (self.n - self.inner.dim()),
        }
    }

    fn name(&self) -> &'static str {
        // Report the inner's identity so solver metrics keep classifying
        // identity-padded solves as plain CG.
        self.inner.name()
    }
}

/// Build the preconditioner a [`PrecondSpec`] describes for `op` (the
/// full noise-shifted K̂). `noise_hint` is σ_n² when the caller knows it
/// (the GP layer does); pass `None` to let the pivoted-Cholesky build
/// self-calibrate its diagonal floor.
///
/// Never fails: a spec the operator cannot support — Jacobi without a
/// cheap [`LinearOp::diag`], a pivoted-Cholesky build that errors —
/// degrades to the identity (recorded under the
/// `solver.precond.fallback` counter) so a solve always proceeds.
pub fn build_preconditioner(
    op: &dyn LinearOp,
    noise_hint: Option<f64>,
    spec: PrecondSpec,
) -> Box<dyn Preconditioner> {
    let fallback = |why: &str| -> Box<dyn Preconditioner> {
        let g = crate::coordinator::metrics::global();
        g.incr("solver.precond.fallback", 1);
        g.incr(&format!("solver.precond.fallback.{why}"), 1);
        Box::new(IdentityPrecond::new(op.dim()))
    };
    match spec {
        PrecondSpec::None => Box::new(IdentityPrecond::new(op.dim())),
        PrecondSpec::Jacobi => match JacobiPrecond::from_op(op) {
            Some(j) => Box::new(j),
            None => fallback("jacobi_no_diag"),
        },
        PrecondSpec::PivChol { rank } => {
            match PivotedCholeskyPrecond::build(op, rank, noise_hint) {
                Ok(p) => Box::new(p),
                Err(_) => fallback("pivchol_build"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::{DenseOp, DiagOp};
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64, noise: f64) -> Matrix {
        let mut rng = Rng::new(seed);
        // Low-rank-dominated + noise floor, the GP covariance shape.
        let g = Matrix::from_fn(n, 6, |_, _| rng.normal());
        let mut a = g.matmul_t(&g);
        a.add_diag(noise);
        a
    }

    #[test]
    fn padded_precond_is_block_diagonal() {
        let a = random_spd(20, 77, 0.5);
        let op = DenseOp(a);
        let inner = PivotedCholeskyPrecond::build(&op, 8, Some(0.5)).unwrap();
        let padded = PaddedPrecond::new(&inner, 24, 2.0);
        assert_eq!(padded.dim(), 24);
        let mut rng = Rng::new(78);
        let r = rng.normal_vec(24);
        let z = padded.apply(&r);
        // Top block = inner apply, tail = Jacobi scaling by 1/tail_diag.
        assert_eq!(&z[..20], inner.apply(&r[..20]).as_slice());
        for i in 20..24 {
            assert_eq!(z[i], r[i] / 2.0);
        }
        // Blocked apply matches column-by-column exactly.
        let block = Matrix::from_fn(24, 3, |_, _| rng.normal());
        let zb = padded.apply_block(&block);
        for j in 0..3 {
            assert_eq!(zb.col(j), padded.apply(&block.col(j)), "column {j}");
        }
        assert_eq!(padded.name(), inner.name());
    }

    #[test]
    fn spec_parse_roundtrip() {
        for s in ["none", "jacobi", "rank:50"] {
            let spec = PrecondSpec::parse(s).unwrap();
            assert_eq!(spec.describe(), s);
        }
        assert!(PrecondSpec::parse("rank:0").is_err());
        assert!(PrecondSpec::parse("rank:x").is_err());
        assert!(PrecondSpec::parse("chol").is_err());
        assert!(PrecondSpec::default().is_none());
    }

    #[test]
    fn identity_is_a_noop() {
        let m = IdentityPrecond::new(3);
        assert_eq!(m.apply(&[1.0, -2.0, 0.5]), vec![1.0, -2.0, 0.5]);
        let b = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.apply_block(&b).data, b.data);
        assert_eq!(m.cost().rank, 0);
    }

    #[test]
    fn jacobi_inverts_a_diagonal_operator() {
        let op = DiagOp(vec![2.0, 4.0, 0.5]);
        let m = JacobiPrecond::from_op(&op).unwrap();
        assert_eq!(m.apply(&[2.0, 4.0, 0.5]), vec![1.0, 1.0, 1.0]);
        // Non-positive diagonals are rejected.
        assert!(JacobiPrecond::new(vec![1.0, 0.0]).is_err());
        assert!(JacobiPrecond::new(vec![1.0, -2.0]).is_err());
    }

    #[test]
    fn pivchol_full_rank_inverts_operator() {
        let n = 25;
        let noise = 0.3;
        let a = random_spd(n, 1, noise);
        let op = DenseOp(a.clone());
        let m = PivotedCholeskyPrecond::build(&op, n, Some(noise)).unwrap();
        // Full rank ⇒ L Lᵀ + σ² I reproduces A exactly ⇒ M⁻¹ A v = v.
        let mut rng = Rng::new(2);
        let v = rng.normal_vec(n);
        let av = a.matvec(&v);
        let z = m.apply(&av);
        assert!(rel_err(&z, &v) < 1e-8, "rel err {}", rel_err(&z, &v));
    }

    #[test]
    fn pivchol_self_calibrates_without_noise_hint() {
        let n = 40;
        let noise = 1e-2;
        let a = random_spd(n, 3, noise);
        let op = DenseOp(a.clone());
        // Rank 6 captures the whole low-rank part; the residual diagonal
        // the δ floor is read off is then ≈ the true noise.
        let m = PivotedCholeskyPrecond::build(&op, 10, None).unwrap();
        assert!(
            m.noise() > 0.1 * noise && m.noise() < 10.0 * noise,
            "self-calibrated δ {} vs true σ² {noise}",
            m.noise()
        );
    }

    #[test]
    fn pivchol_apply_block_matches_apply() {
        let n = 30;
        let a = random_spd(n, 4, 0.05);
        let op = DenseOp(a);
        let m = PivotedCholeskyPrecond::build(&op, 8, Some(0.05)).unwrap();
        let mut rng = Rng::new(5);
        let r = Matrix::from_fn(n, 4, |_, _| rng.normal());
        let blocked = m.apply_block(&r);
        for j in 0..4 {
            let one = m.apply(&r.col(j));
            assert!(rel_err(&blocked.col(j), &one) < 1e-13);
        }
    }

    #[test]
    fn pivchol_pivots_follow_large_diagonal_entries() {
        // One dominant coordinate: the first pivot must find it.
        let mut a = Matrix::eye(10);
        a.set(7, 7, 50.0);
        let op = DenseOp(a);
        let m = PivotedCholeskyPrecond::build(&op, 3, None).unwrap();
        assert_eq!(m.pivots[0], 7);
    }

    #[test]
    fn pivchol_stops_early_on_exact_low_rank() {
        // Rank-2 + noise: requesting rank 10 must stop once the residual
        // diagonal is exhausted (numerically), not fabricate columns.
        let n = 20;
        let mut rng = Rng::new(6);
        let g = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let mut a = g.matmul_t(&g);
        a.add_diag(1e-3);
        let op = DenseOp(a);
        let m = PivotedCholeskyPrecond::build(&op, 10, Some(1e-3)).unwrap();
        assert!(m.rank() <= 4, "rank {} for a rank-2 kernel", m.rank());
        // At most one fetched column is discarded by the post-fetch
        // exhaustion check.
        assert!(
            m.cost().setup_matvecs <= m.rank() + 1,
            "{} matvecs for rank {}",
            m.cost().setup_matvecs,
            m.rank()
        );
    }

    #[test]
    fn diagless_operator_still_builds_a_valid_factor() {
        // No diag() ⇒ pivots are evenly spread instead of greedy, but the
        // factor is still a valid partial Cholesky (each column is
        // normalized by its exact residual diagonal read off the fetched
        // column), so with enough budget it still reproduces the operator.
        struct Opaque(Matrix);
        impl LinearOp for Opaque {
            fn dim(&self) -> usize {
                self.0.rows
            }
            fn matvec(&self, v: &[f64]) -> Vec<f64> {
                self.0.matvec(v)
            }
        }
        let n = 30;
        let noise = 0.05;
        let a = random_spd(n, 8, noise);
        let op = Opaque(a.clone());
        assert!(op.diag().is_none());
        let m = PivotedCholeskyPrecond::build(&op, n, Some(noise)).unwrap();
        let mut rng = Rng::new(9);
        let v = rng.normal_vec(n);
        let av = a.matvec(&v);
        let z = m.apply(&av);
        assert!(rel_err(&z, &v) < 1e-6, "rel err {}", rel_err(&z, &v));
    }

    #[test]
    fn build_preconditioner_falls_back_to_identity() {
        // An operator with no diag() override: Jacobi degrades to the
        // identity instead of failing the solve.
        struct Opaque(usize);
        impl crate::operators::LinearOp for Opaque {
            fn dim(&self) -> usize {
                self.0
            }
            fn matvec(&self, v: &[f64]) -> Vec<f64> {
                v.to_vec()
            }
        }
        let m = build_preconditioner(&Opaque(4), None, PrecondSpec::Jacobi);
        assert_eq!(m.name(), "identity");
        let m = build_preconditioner(&Opaque(4), None, PrecondSpec::None);
        assert_eq!(m.name(), "identity");
    }
}
