//! Lanczos decomposition (paper §3, Lemma 3.2).
//!
//! Given a symmetric operator A and a probe vector b, r Lanczos iterations
//! produce `Q (n×r, orthonormal)` and tridiagonal `T (r×r)` with
//! `A ≈ Q T Qᵀ` — at the cost of r MVMs. Full reorthogonalization keeps Q
//! numerically orthogonal (we store Q anyway, so the O(nr²) cost is free
//! relative to the downstream Lemma-3.1 contraction).

use crate::linalg::{axpy, dot, norm2, Matrix};
use crate::operators::{LanczosFactor, LinearOp};

/// Raw Lanczos recurrence output.
#[derive(Clone, Debug)]
pub struct LanczosResult {
    /// n × r orthonormal basis.
    pub q: Matrix,
    /// Diagonal of T (length r).
    pub alphas: Vec<f64>,
    /// Off-diagonal of T (length r−1).
    pub betas: Vec<f64>,
}

impl LanczosResult {
    /// Rank actually reached (early breakdown may stop before `max_rank`).
    pub fn rank(&self) -> usize {
        self.alphas.len()
    }

    /// Dense r×r tridiagonal T.
    pub fn t_dense(&self) -> Matrix {
        let r = self.rank();
        Matrix::from_fn(r, r, |i, j| {
            if i == j {
                self.alphas[i]
            } else if i.abs_diff(j) == 1 {
                self.betas[i.min(j)]
            } else {
                0.0
            }
        })
    }

    /// Package as a [`LanczosFactor`] for the Lemma-3.1 machinery.
    pub fn into_factor(self) -> LanczosFactor {
        let t = self.t_dense();
        LanczosFactor { q: self.q, t }
    }
}

/// Run up to `max_rank` Lanczos iterations of `a` from start vector `b`.
///
/// Stops early on breakdown (β below `tol`), which signals that the Krylov
/// space is exhausted — for low-rank kernel matrices this happens fast and
/// is exactly why SKIP works with tiny r.
pub fn lanczos(
    a: &dyn LinearOp,
    b: &[f64],
    max_rank: usize,
    tol: f64,
) -> LanczosResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let max_rank = max_rank.min(n).max(1);
    let mut q = Matrix::zeros(n, max_rank);
    let mut alphas = Vec::with_capacity(max_rank);
    let mut betas = Vec::with_capacity(max_rank.saturating_sub(1));

    let nb = norm2(b);
    assert!(nb > 0.0, "lanczos: zero start vector");
    let mut qj: Vec<f64> = b.iter().map(|&x| x / nb).collect();
    q.set_col(0, &qj);
    let mut q_prev = vec![0.0; n];
    let mut beta_prev = 0.0;

    for j in 0..max_rank {
        let mut w = a.matvec(&qj);
        let alpha = dot(&qj, &w);
        alphas.push(alpha);
        // w ← w − α qⱼ − β qⱼ₋₁
        axpy(-alpha, &qj, &mut w);
        if j > 0 {
            axpy(-beta_prev, &q_prev, &mut w);
        }
        // Full reorthogonalization against all stored columns (twice is
        // enough — "twice is enough" of Parlett & Kahan).
        for _ in 0..2 {
            for k in 0..=j {
                let col = q.col(k);
                let c = dot(&col, &w);
                axpy(-c, &col, &mut w);
            }
        }
        let beta = norm2(&w);
        if j + 1 == max_rank {
            break;
        }
        if beta < tol {
            break; // Krylov space exhausted.
        }
        betas.push(beta);
        q_prev = qj;
        beta_prev = beta;
        qj = w.iter().map(|&x| x / beta).collect();
        q.set_col(j + 1, &qj);
    }

    // Shrink Q to the achieved rank.
    let r = alphas.len();
    if r < max_rank {
        let mut qs = Matrix::zeros(n, r);
        for k in 0..r {
            qs.set_col(k, &q.col(k));
        }
        q = qs;
    }
    LanczosResult { q, alphas, betas }
}

/// Run Lanczos from every column of `probes` in lockstep, fusing the
/// per-iteration MVMs of all still-active probes into one
/// [`LinearOp::matmat`] call — the batched probe path used by SLQ, so p
/// trace probes share each operator traversal instead of paying p
/// separate ones.
///
/// Per probe the recurrence (normalization, reorthogonalization, early
/// breakdown at `tol`) is *exactly* the one [`lanczos`] runs: a probe that
/// breaks down is frozen and dropped from later block MVMs, and with a
/// `matmat` that matches column-wise `matvec`, each returned
/// [`LanczosResult`] is identical to the sequential call on that column.
pub fn lanczos_batch(
    a: &dyn LinearOp,
    probes: &Matrix,
    max_rank: usize,
    tol: f64,
) -> Vec<LanczosResult> {
    let n = a.dim();
    assert_eq!(probes.rows, n);
    let t = probes.cols;
    let max_rank = max_rank.min(n).max(1);

    struct ProbeState {
        q: Matrix,
        alphas: Vec<f64>,
        betas: Vec<f64>,
        qj: Vec<f64>,
        q_prev: Vec<f64>,
        beta_prev: f64,
        done: bool,
    }

    let mut states: Vec<ProbeState> = (0..t)
        .map(|j| {
            let b = probes.col(j);
            let nb = norm2(&b);
            assert!(nb > 0.0, "lanczos_batch: zero probe column {j}");
            let qj: Vec<f64> = b.iter().map(|&x| x / nb).collect();
            let mut q = Matrix::zeros(n, max_rank);
            q.set_col(0, &qj);
            ProbeState {
                q,
                alphas: Vec::with_capacity(max_rank),
                betas: Vec::with_capacity(max_rank.saturating_sub(1)),
                qj,
                q_prev: vec![0.0; n],
                beta_prev: 0.0,
                done: false,
            }
        })
        .collect();

    for step in 0..max_rank {
        let active: Vec<usize> = (0..t).filter(|&j| !states[j].done).collect();
        if active.is_empty() {
            break;
        }
        // Every active probe has completed exactly `step` iterations, so
        // one block MVM serves them all.
        let mut block = Matrix::zeros(n, active.len());
        for (c, &j) in active.iter().enumerate() {
            block.set_col(c, &states[j].qj);
        }
        let w_block = a.matmat(&block);
        for (c, &j) in active.iter().enumerate() {
            let st = &mut states[j];
            let mut w = w_block.col(c);
            let alpha = dot(&st.qj, &w);
            st.alphas.push(alpha);
            axpy(-alpha, &st.qj, &mut w);
            if step > 0 {
                axpy(-st.beta_prev, &st.q_prev, &mut w);
            }
            for _ in 0..2 {
                for k in 0..=step {
                    let col = st.q.col(k);
                    let cdot = dot(&col, &w);
                    axpy(-cdot, &col, &mut w);
                }
            }
            let beta = norm2(&w);
            if step + 1 == max_rank || beta < tol {
                st.done = true;
                continue;
            }
            st.betas.push(beta);
            st.q_prev = std::mem::take(&mut st.qj);
            st.beta_prev = beta;
            st.qj = w.iter().map(|&x| x / beta).collect();
            st.q.set_col(step + 1, &st.qj);
        }
    }

    states
        .into_iter()
        .map(|st| {
            let r = st.alphas.len();
            let mut q = st.q;
            if r < max_rank {
                let mut qs = Matrix::zeros(n, r);
                for k in 0..r {
                    qs.set_col(k, &q.col(k));
                }
                q = qs;
            }
            LanczosResult { q, alphas: st.alphas, betas: st.betas }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(0.5);
        a
    }

    #[test]
    fn q_is_orthonormal() {
        let a = DenseOp(random_spd(30, 1));
        let mut rng = Rng::new(2);
        let b = rng.normal_vec(30);
        let res = lanczos(&a, &b, 10, 1e-12);
        let qtq = res.q.t_matmul(&res.q);
        assert!(qtq.max_abs_diff(&Matrix::eye(res.rank())) < 1e-10);
    }

    #[test]
    fn full_rank_is_exact() {
        let n = 12;
        let dense = random_spd(n, 3);
        let a = DenseOp(dense.clone());
        let mut rng = Rng::new(4);
        let b = rng.normal_vec(n);
        let res = lanczos(&a, &b, n, 1e-14);
        let f = res.into_factor();
        // Exact after n steps (if no early breakdown).
        if f.rank() == n {
            assert!(f.to_dense().max_abs_diff(&dense) < 1e-7);
        }
        // In any case the action on b is exact.
        let v = dense.matvec(&b);
        let got = f.matvec(&b);
        assert!(rel_err(&got, &v) < 1e-8);
    }

    #[test]
    fn low_rank_matrix_recovers_with_small_r() {
        // Rank-3 PSD matrix: Lanczos should be near-exact at r = 4.
        let n = 40;
        let mut rng = Rng::new(5);
        let g = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let dense = g.matmul_t(&g);
        let a = DenseOp(dense.clone());
        let b = rng.normal_vec(n);
        let res = lanczos(&a, &b, 8, 1e-10);
        let f = res.into_factor();
        assert!(f.rank() <= 5, "rank {} should reflect breakdown", f.rank());
        let v = rng.normal_vec(n);
        let got = f.matvec(&v);
        let want = dense.matvec(&v);
        assert!(rel_err(&got, &want) < 1e-6, "err {}", rel_err(&got, &want));
    }

    #[test]
    fn rbf_kernel_matrix_fast_decay() {
        // Smooth kernels have fast spectral decay — small r gives small
        // error; this is the empirical engine behind Figure 2 (left).
        use crate::kernels::ProductKernel;
        let mut rng = Rng::new(6);
        let n = 60;
        let xs = Matrix::from_fn(n, 1, |_, _| rng.normal());
        let k = ProductKernel::rbf(1, 1.0, 1.0);
        let dense = k.gram_sym(&xs);
        let a = DenseOp(dense.clone());
        let b = rng.normal_vec(n);
        let f = lanczos(&a, &b, 20, 1e-12).into_factor();
        let v = rng.normal_vec(n);
        assert!(rel_err(&f.matvec(&v), &dense.matvec(&v)) < 1e-5);
    }

    #[test]
    fn batch_matches_sequential_per_probe() {
        let a = DenseOp(random_spd(35, 10));
        let mut rng = Rng::new(11);
        let mut probes = Matrix::zeros(35, 4);
        for j in 0..4 {
            probes.set_col(j, &rng.normal_vec(35));
        }
        let batch = lanczos_batch(&a, &probes, 12, 1e-10);
        assert_eq!(batch.len(), 4);
        for (j, got) in batch.iter().enumerate() {
            let want = lanczos(&a, &probes.col(j), 12, 1e-10);
            assert_eq!(got.rank(), want.rank(), "probe {j} rank");
            for (ga, wa) in got.alphas.iter().zip(&want.alphas) {
                assert!((ga - wa).abs() < 1e-12, "probe {j} alphas");
            }
            for (gb, wb) in got.betas.iter().zip(&want.betas) {
                assert!((gb - wb).abs() < 1e-12, "probe {j} betas");
            }
            assert!(got.q.max_abs_diff(&want.q) < 1e-12, "probe {j} basis");
        }
    }

    #[test]
    fn batch_handles_early_breakdown_per_probe() {
        // Rank-2 PSD matrix: every probe breaks down by step ~3 while the
        // lockstep loop keeps the others consistent.
        let n = 30;
        let mut rng = Rng::new(12);
        let g = Matrix::from_fn(n, 2, |_, _| rng.normal());
        let a = DenseOp(g.matmul_t(&g));
        let mut probes = Matrix::zeros(n, 3);
        for j in 0..3 {
            probes.set_col(j, &rng.normal_vec(n));
        }
        let batch = lanczos_batch(&a, &probes, 10, 1e-10);
        for (j, res) in batch.iter().enumerate() {
            let want = lanczos(&a, &probes.col(j), 10, 1e-10);
            assert_eq!(res.rank(), want.rank(), "probe {j}");
            assert!(res.rank() <= 4, "probe {j} should break down early");
        }
    }

    #[test]
    fn tridiagonal_structure() {
        let a = DenseOp(random_spd(15, 7));
        let mut rng = Rng::new(8);
        let b = rng.normal_vec(15);
        let res = lanczos(&a, &b, 6, 1e-12);
        let t = res.t_dense();
        for i in 0..res.rank() {
            for j in 0..res.rank() {
                if i.abs_diff(j) > 1 {
                    assert_eq!(t.get(i, j), 0.0);
                }
            }
        }
    }
}
