//! (Preconditioned) conjugate gradients (paper §2.2; Yadav et al. 2021).
//!
//! Solves `A x = b` for symmetric positive-definite `A` using only MVMs —
//! the core of MVM-based GP inference. Allocation-free inner loop: all
//! work buffers are allocated once up front.
//!
//! Every solve is *preconditioned* CG under the hood. The preconditioner
//! comes from [`CgConfig::precond`] (built per solve by
//! [`build_preconditioner`]) or, for callers that amortize setup across
//! several solves against one operator, is passed explicitly to
//! [`cg_solve_with`] together with an optional warm-start iterate `x0`.
//! With the identity preconditioner and no warm start the recurrence —
//! every float operation of it — is the classic unpreconditioned CG this
//! module always ran.
//!
//! Convergence is judged on the **preconditioned residual norm**:
//!
//! ```text
//! ‖r_i‖_{M⁻¹} ≤ tol · ‖b‖_{M⁻¹},   ‖v‖_{M⁻¹} = √(vᵀ M⁻¹ v)
//! ```
//!
//! which is the norm PCG minimizes in and costs nothing extra (the
//! recurrence already computes `rᵀz`). For `M = I` it is exactly the
//! historical `‖r‖/‖b‖ ≤ tol` criterion.
//!
//! ```
//! use skip_gp::linalg::Matrix;
//! use skip_gp::operators::DenseOp;
//! use skip_gp::solvers::{
//!     build_preconditioner, cg_solve, cg_solve_with, CgConfig, PrecondSpec,
//! };
//!
//! let a = DenseOp(Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]));
//! let b = vec![1.0, 2.0];
//!
//! // Plain CG…
//! let plain = cg_solve(&a, &b, CgConfig::default());
//! // …and PCG with a rank-2 pivoted-Cholesky preconditioner: same
//! // solution (preconditioning never changes the answer), fewer
//! // iterations on ill-conditioned systems.
//! let m = build_preconditioner(&a, None, PrecondSpec::PivChol { rank: 2 });
//! let pre = cg_solve_with(&a, &b, m.as_ref(), None, CgConfig::default());
//! assert!(plain.converged && pre.converged);
//! assert!(pre.iters <= plain.iters);
//! assert!(plain.x.iter().zip(&pre.x).all(|(u, v)| (u - v).abs() < 1e-8));
//!
//! // Warm start: seeding with the solved x returns it bitwise, 0 iters.
//! let again = cg_solve_with(&a, &b, m.as_ref(), Some(&pre.x), CgConfig::default());
//! assert_eq!(again.iters, 0);
//! assert_eq!(again.x, pre.x);
//! ```

use super::precond::{build_preconditioner, Preconditioner, PrecondSpec};
use super::refine::{refined_cg_solve, Precision};
use crate::linalg::{axpy, dot, norm2};
use crate::operators::LinearOp;

/// CG configuration: iteration/tolerance budget plus the preconditioner
/// specification threaded from `MvmGpConfig` / `SnapshotConfig` / the
/// `--precond` CLI flag.
#[derive(Clone, Copy, Debug)]
pub struct CgConfig {
    /// Maximum iterations (paper: p, a small constant in practice).
    pub max_iters: usize,
    /// Relative tolerance on the preconditioned residual norm
    /// `‖r‖_{M⁻¹}/‖b‖_{M⁻¹}` (= `‖r‖/‖b‖` unpreconditioned).
    pub tol: f64,
    /// Which preconditioner [`cg_solve`]/[`block_cg_solve`] build for the
    /// solve ([`PrecondSpec::None`] = classic unpreconditioned CG).
    ///
    /// [`block_cg_solve`]: super::block_cg::block_cg_solve
    pub precond: PrecondSpec,
    /// Arithmetic policy: [`Precision::F64`] (default, historical path,
    /// bitwise unchanged) or [`Precision::Mixed`] (f32 operator storage
    /// under f64 iterative refinement — same residual certificate, see
    /// [`super::refine`]).
    pub precision: Precision,
}

impl Default for CgConfig {
    fn default() -> Self {
        CgConfig {
            max_iters: 200,
            tol: 1e-8,
            precond: PrecondSpec::None,
            precision: Precision::F64,
        }
    }
}

/// CG solution with convergence diagnostics.
#[derive(Clone, Debug)]
pub struct CgSolution {
    /// The iterate at exit (the solution when [`converged`] is true).
    ///
    /// [`converged`]: CgSolution::converged
    pub x: Vec<f64>,
    /// Iterations run (0 when the right-hand side is zero or a warm-start
    /// seed already met the tolerance).
    pub iters: usize,
    /// Final relative preconditioned residual `‖r‖_{M⁻¹}/‖b‖_{M⁻¹}`.
    pub rel_residual: f64,
    /// Whether [`rel_residual`] met [`CgConfig::tol`].
    ///
    /// [`rel_residual`]: CgSolution::rel_residual
    pub converged: bool,
}

/// Solve `A x = b` by (preconditioned) conjugate gradients, building the
/// preconditioner [`CgConfig::precond`] describes.
///
/// Callers that solve repeatedly against one operator should build the
/// preconditioner once ([`build_preconditioner`]) and call
/// [`cg_solve_with`], which also accepts a warm-start iterate.
///
/// Every run records its iteration count (and any convergence failure)
/// into the global metrics registry under `solver.cg.*` (`solver.pcg.*`
/// when preconditioned; [`crate::coordinator::metrics::record_solver`]),
/// so session summaries can report p50/p99 solver effort.
pub fn cg_solve(a: &dyn LinearOp, b: &[f64], cfg: CgConfig) -> CgSolution {
    let m = build_preconditioner(a, None, cfg.precond);
    cg_solve_with(a, b, m.as_ref(), None, cfg)
}

/// Solve `A x = b` by PCG with an explicit preconditioner and optional
/// warm start.
///
/// `x0` seeds the iteration: the solver starts from `r₀ = b − A x₀` (one
/// extra MVM) instead of `b`, so a seed near the solution — the previous
/// step's α in an optimizer loop, the pre-refresh α in a cache refresh —
/// converges in a handful of iterations, and a seed that already meets
/// the tolerance is returned **bitwise unchanged** with `iters == 0`.
/// Warm starts never change the limit the iteration converges to; only
/// where it starts.
///
/// [`CgConfig::precision`] selects the arithmetic: `F64` runs the classic
/// recurrence below bitwise unchanged; `Mixed` routes through
/// [`refined_cg_solve`](super::refine::refined_cg_solve) — f32 inner
/// iterations under an f64 refinement loop meeting the same certificate.
pub fn cg_solve_with(
    a: &dyn LinearOp,
    b: &[f64],
    m: &dyn Preconditioner,
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> CgSolution {
    match cfg.precision {
        Precision::F64 => cg_solve_f64(a, b, m, x0, cfg),
        Precision::Mixed => refined_cg_solve(a, b, m, x0, cfg),
    }
}

/// The f64 PCG recurrence behind [`cg_solve_with`] — also the certifying
/// fallback of the mixed-precision path (`super::refine`), which must
/// reach it *without* re-entering the precision router.
pub(crate) fn cg_solve_f64(
    a: &dyn LinearOp,
    b: &[f64],
    m: &dyn Preconditioner,
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> CgSolution {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(m.dim(), n, "preconditioner dimension must match operator");
    let solver = if m.name() == "identity" { "cg" } else { "pcg" };
    let nb = norm2(b);
    if nb == 0.0 {
        crate::coordinator::metrics::record_solver(solver, 0, true);
        return CgSolution { x: vec![0.0; n], iters: 0, rel_residual: 0.0, converged: true };
    }
    // A mismatched-length seed is ignored rather than asserted: callers
    // thread "whatever the previous solve produced" here and a stale
    // shape just means a cold start.
    let x0 = x0.filter(|x| x.len() == n);
    let seeded = x0.is_some();
    let (mut x, mut r, bnorm_m) = match x0 {
        Some(x0) => {
            let ax = a.matvec(x0);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
            // ‖b‖_{M⁻¹} must be computed from b itself when r₀ ≠ b.
            let zb = m.apply(b);
            (x0.to_vec(), r, Some(dot(b, &zb).max(0.0).sqrt()))
        }
        None => (vec![0.0; n], b.to_vec(), None),
    };
    let mut z = m.apply(&r);
    let mut rz = dot(&r, &z).max(0.0);
    // Cold starts have r₀ = b, so ‖b‖_{M⁻¹} is the rz just computed.
    let bnorm_m = bnorm_m.unwrap_or_else(|| rz.sqrt());
    let g = crate::coordinator::metrics::global();
    if seeded {
        g.incr("solver.warm.seeded", 1);
    }
    if rz.sqrt() <= cfg.tol * bnorm_m {
        // Zero iterations: a warm seed already inside the tolerance is
        // returned bitwise (the "no worse than what you gave me"
        // guarantee warm-start callers rely on).
        if seeded {
            g.incr("solver.warm.hit", 1);
        }
        crate::coordinator::metrics::record_solver(solver, 0, true);
        let rel = if bnorm_m > 0.0 { rz.sqrt() / bnorm_m } else { 0.0 };
        return CgSolution { x, iters: 0, rel_residual: rel, converged: true };
    }
    let mut p = z.clone();
    let mut iters = 0;
    let mut converged = false;
    for _ in 0..cfg.max_iters {
        iters += 1;
        let ap = a.matvec(&p);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // Not PD to working precision — bail with current iterate.
            break;
        }
        let alpha = rz / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        z = m.apply(&r);
        let rz_new = dot(&r, &z).max(0.0);
        if rz_new.sqrt() <= cfg.tol * bnorm_m {
            rz = rz_new;
            converged = true;
            break;
        }
        let beta = rz_new / rz;
        for (pi, &zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
        rz = rz_new;
    }
    let rel = if bnorm_m > 0.0 { rz.sqrt() / bnorm_m } else { 0.0 };
    let converged = converged || rel <= cfg.tol;
    crate::coordinator::metrics::record_solver(solver, iters, converged);
    CgSolution { x, iters, rel_residual: rel, converged }
}

/// Solve `A X = B` for multiple right-hand sides (columns of `b_cols`),
/// sequentially — the *serial reference* the batched engine is measured
/// against, kept for tests and paired benchmarks. Production multi-RHS
/// solves should use [`block_cg_solve`](super::block_cg::block_cg_solve),
/// which fuses the per-iteration MVMs of all columns into one operator
/// traversal (and takes the same preconditioner/warm-start options via
/// [`block_cg_solve_with`](super::block_cg::block_cg_solve_with)).
///
/// ```
/// use skip_gp::linalg::Matrix;
/// use skip_gp::operators::DenseOp;
/// use skip_gp::solvers::{block_cg_solve, cg_solve_many, CgConfig};
///
/// let a = DenseOp(Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]));
/// let cols = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
/// let serial = cg_solve_many(&a, &cols, CgConfig::default());
///
/// // The batched engine gives the same per-column solutions with one
/// // fused block MVM per iteration instead of one MVM per column:
/// let mut block_b = Matrix::zeros(2, 2);
/// for (j, c) in cols.iter().enumerate() {
///     block_b.set_col(j, c);
/// }
/// let block = block_cg_solve(&a, &block_b, CgConfig::default());
/// for (j, s) in serial.iter().enumerate() {
///     assert!(s.x.iter().zip(&block.x.col(j)).all(|(u, v)| (u - v).abs() < 1e-10));
/// }
/// ```
pub fn cg_solve_many(
    a: &dyn LinearOp,
    b_cols: &[Vec<f64>],
    cfg: CgConfig,
) -> Vec<CgSolution> {
    b_cols.iter().map(|b| cg_solve(a, b, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::solvers::precond::PivotedCholeskyPrecond;
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.05);
        a
    }

    #[test]
    fn matches_cholesky_solve() {
        let dense = random_spd(30, 1);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(2);
        let b = rng.normal_vec(30);
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged, "residual {}", sol.rel_residual);
        let want = Cholesky::new(&dense).unwrap().solve(&b);
        assert!(rel_err(&sol.x, &want) < 1e-6);
    }

    #[test]
    fn identity_solves_immediately() {
        let op = DenseOp(Matrix::eye(10));
        let b: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged);
        assert!(sol.iters <= 2);
        assert!(rel_err(&sol.x, &b) < 1e-10);
    }

    #[test]
    fn zero_rhs() {
        let op = DenseOp(Matrix::eye(5));
        let sol = cg_solve(&op, &[0.0; 5], CgConfig::default());
        assert!(sol.converged);
        assert_eq!(sol.x, vec![0.0; 5]);
    }

    #[test]
    fn exact_in_n_iterations() {
        let n = 20;
        let dense = random_spd(n, 3);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(4);
        let b = rng.normal_vec(n);
        let cfg = CgConfig { max_iters: n + 5, tol: 1e-12, ..Default::default() };
        let sol = cg_solve(&op, &b, cfg);
        let back = dense.matvec(&sol.x);
        assert!(rel_err(&back, &b) < 1e-8);
    }

    #[test]
    fn well_conditioned_converges_fast() {
        // A = I + small perturbation → few iterations (paper: p depends on
        // conditioning, not n).
        let n = 200;
        let mut rng = Rng::new(5);
        let g = Matrix::from_fn(n, 3, |_, _| rng.normal() * 0.1);
        let mut dense = g.matmul_t(&g);
        dense.add_diag(1.0);
        let op = DenseOp(dense);
        let b = rng.normal_vec(n);
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged);
        assert!(sol.iters < 20, "iters {}", sol.iters);
    }

    #[test]
    fn many_rhs() {
        let dense = random_spd(15, 6);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(7);
        let bs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(15)).collect();
        let sols = cg_solve_many(&op, &bs, CgConfig::default());
        for (sol, b) in sols.iter().zip(&bs) {
            assert!(sol.converged);
            assert!(rel_err(&dense.matvec(&sol.x), b) < 1e-6);
        }
    }

    #[test]
    fn pcg_agrees_with_cg_and_iterates_less() {
        // Low-rank + small noise: the ill-conditioned shape PCG targets.
        let n = 120;
        let mut rng = Rng::new(8);
        let g = Matrix::from_fn(n, 10, |_, _| rng.normal());
        let mut dense = g.matmul_t(&g);
        let noise = 1e-3;
        dense.add_diag(noise);
        let op = DenseOp(dense);
        let b = rng.normal_vec(n);
        let cfg = CgConfig { max_iters: 500, tol: 1e-10, ..Default::default() };
        let plain = cg_solve(&op, &b, cfg);
        let m = PivotedCholeskyPrecond::build(&op, 15, Some(noise)).unwrap();
        let pre = cg_solve_with(&op, &b, &m, None, cfg);
        assert!(plain.converged && pre.converged);
        assert!(rel_err(&pre.x, &plain.x) < 1e-8);
        assert!(
            pre.iters * 3 <= plain.iters,
            "pcg {} vs cg {} iters",
            pre.iters,
            plain.iters
        );
    }

    #[test]
    fn warm_start_with_solution_is_bitwise_noop() {
        let dense = random_spd(25, 9);
        let op = DenseOp(dense);
        let mut rng = Rng::new(10);
        let b = rng.normal_vec(25);
        // Seed from a solve two digits tighter than the warm solve's
        // tolerance, so the seed sits squarely inside it.
        let cold = cg_solve(
            &op,
            &b,
            CgConfig { max_iters: 500, tol: 1e-10, ..Default::default() },
        );
        assert!(cold.converged);
        let m = crate::solvers::precond::IdentityPrecond::new(25);
        let warm = cg_solve_with(&op, &b, &m, Some(&cold.x), CgConfig::default());
        assert_eq!(warm.iters, 0);
        assert!(warm.converged);
        assert_eq!(warm.x, cold.x, "seed inside tolerance must return bitwise");
    }

    #[test]
    fn warm_start_mismatched_length_is_ignored() {
        let op = DenseOp(Matrix::eye(6));
        let b = vec![1.0; 6];
        let m = crate::solvers::precond::IdentityPrecond::new(6);
        let sol = cg_solve_with(&op, &b, &m, Some(&[1.0, 2.0]), CgConfig::default());
        assert!(sol.converged);
        assert!(rel_err(&sol.x, &b) < 1e-12);
    }
}
