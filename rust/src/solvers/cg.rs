//! Conjugate gradients (paper §2.2).
//!
//! Solves `A x = b` for symmetric positive-definite `A` using only MVMs —
//! the core of MVM-based GP inference. Allocation-free inner loop: all
//! work buffers are allocated once up front.

use crate::linalg::{axpy, dot, norm2};
use crate::operators::LinearOp;

/// CG configuration.
#[derive(Clone, Copy, Debug)]
pub struct CgConfig {
    /// Maximum iterations (paper: p, a small constant in practice).
    pub max_iters: usize,
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub tol: f64,
}

impl Default for CgConfig {
    fn default() -> Self {
        CgConfig { max_iters: 200, tol: 1e-8 }
    }
}

/// CG solution with convergence diagnostics.
#[derive(Clone, Debug)]
pub struct CgSolution {
    pub x: Vec<f64>,
    pub iters: usize,
    pub rel_residual: f64,
    pub converged: bool,
}

/// Solve `A x = b` by conjugate gradients.
///
/// Every run records its iteration count (and any convergence failure)
/// into the global metrics registry under `solver.cg.*`
/// ([`crate::coordinator::metrics::record_solver`]), so session summaries
/// can report p50/p99 solver effort.
pub fn cg_solve(a: &dyn LinearOp, b: &[f64], cfg: CgConfig) -> CgSolution {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let nb = norm2(b);
    if nb == 0.0 {
        crate::coordinator::metrics::record_solver("cg", 0, true);
        return CgSolution { x: vec![0.0; n], iters: 0, rel_residual: 0.0, converged: true };
    }
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = b.to_vec();
    let mut rs_old = dot(&r, &r);
    let mut iters = 0;
    for _ in 0..cfg.max_iters {
        iters += 1;
        let ap = a.matvec(&p);
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // Not PD to working precision — bail with current iterate.
            break;
        }
        let alpha = rs_old / pap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() <= cfg.tol * nb {
            rs_old = rs_new;
            break;
        }
        let beta = rs_new / rs_old;
        for (pi, &ri) in p.iter_mut().zip(&r) {
            *pi = ri + beta * *pi;
        }
        rs_old = rs_new;
    }
    let rel = rs_old.sqrt() / nb;
    let converged = rel <= cfg.tol;
    crate::coordinator::metrics::record_solver("cg", iters, converged);
    CgSolution { x, iters, rel_residual: rel, converged }
}

/// Solve `A X = B` for multiple right-hand sides (columns of `b_cols`),
/// sequentially — the *serial reference* the batched engine is measured
/// against. Production multi-RHS solves should use
/// [`block_cg_solve`](super::block_cg::block_cg_solve), which fuses the
/// per-iteration MVMs of all columns into one operator traversal.
pub fn cg_solve_many(
    a: &dyn LinearOp,
    b_cols: &[Vec<f64>],
    cfg: CgConfig,
) -> Vec<CgSolution> {
    b_cols.iter().map(|b| cg_solve(a, b, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, Matrix};
    use crate::operators::DenseOp;
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.05);
        a
    }

    #[test]
    fn matches_cholesky_solve() {
        let dense = random_spd(30, 1);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(2);
        let b = rng.normal_vec(30);
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged, "residual {}", sol.rel_residual);
        let want = Cholesky::new(&dense).unwrap().solve(&b);
        assert!(rel_err(&sol.x, &want) < 1e-6);
    }

    #[test]
    fn identity_solves_immediately() {
        let op = DenseOp(Matrix::eye(10));
        let b: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged);
        assert!(sol.iters <= 2);
        assert!(rel_err(&sol.x, &b) < 1e-10);
    }

    #[test]
    fn zero_rhs() {
        let op = DenseOp(Matrix::eye(5));
        let sol = cg_solve(&op, &[0.0; 5], CgConfig::default());
        assert!(sol.converged);
        assert_eq!(sol.x, vec![0.0; 5]);
    }

    #[test]
    fn exact_in_n_iterations() {
        let n = 20;
        let dense = random_spd(n, 3);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(4);
        let b = rng.normal_vec(n);
        let sol = cg_solve(&op, &b, CgConfig { max_iters: n + 5, tol: 1e-12 });
        let back = dense.matvec(&sol.x);
        assert!(rel_err(&back, &b) < 1e-8);
    }

    #[test]
    fn well_conditioned_converges_fast() {
        // A = I + small perturbation → few iterations (paper: p depends on
        // conditioning, not n).
        let n = 200;
        let mut rng = Rng::new(5);
        let g = Matrix::from_fn(n, 3, |_, _| rng.normal() * 0.1);
        let mut dense = g.matmul_t(&g);
        dense.add_diag(1.0);
        let op = DenseOp(dense);
        let b = rng.normal_vec(n);
        let sol = cg_solve(&op, &b, CgConfig::default());
        assert!(sol.converged);
        assert!(sol.iters < 20, "iters {}", sol.iters);
    }

    #[test]
    fn many_rhs() {
        let dense = random_spd(15, 6);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(7);
        let bs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(15)).collect();
        let sols = cg_solve_many(&op, &bs, CgConfig::default());
        for (sol, b) in sols.iter().zip(&bs) {
            assert!(sol.converged);
            assert!(rel_err(&dense.matvec(&sol.x), b) < 1e-6);
        }
    }
}
