//! Block (preconditioned) conjugate gradients: solve `A X = B` for t
//! right-hand sides with one operator traversal per iteration.
//!
//! The paper's inference loop needs many simultaneous solves against the
//! same `K̂` — the predictive solve `α = K̂⁻¹y` next to the Hutchinson
//! trace probes `K̂⁻¹zᵢ` of the gradient (§2.2), or a batch of test-time
//! solves. Serial CG pays the operator once *per RHS per iteration*; for
//! SKIP that is t separate O(r²n) Lemma-3.1 contractions whose memory
//! traffic dominates. This solver runs the t standard PCG recurrences in
//! lockstep and fuses their MVMs into a single [`LinearOp::matmat`] call
//! (and their preconditioner applications into a single
//! [`Preconditioner::apply_block`]), so the structured operator amortizes
//! its traversal across the block (fused contraction, paired FFTs, shared
//! stencil decode — see each operator's `matmat`).
//!
//! Columns are tracked independently: each has its own α/β scalars,
//! residual, and iteration count, each converges against **its own**
//! right-hand side's preconditioned norm
//! (`‖r_j‖_{M⁻¹} ≤ tol·‖b_j‖_{M⁻¹}` — never a shared block norm, so a
//! small-norm column next to a large-norm one is still solved to its own
//! relative accuracy; pinned by the mixed-norm regression test in
//! `rust/tests/solver_props.rs`), and a column that converges (or hits a
//! non-PD breakdown) is frozen and dropped from subsequent block MVMs.
//! With an exact `matmat` (one that matches column-wise `matvec`, which
//! every fast path in this crate does to rounding), the per-column
//! iterates are identical to t independent [`cg_solve`] runs — verified
//! by the `matmat_props` property tests to 1e-8 and tighter.
//!
//! ```
//! use skip_gp::linalg::Matrix;
//! use skip_gp::operators::DenseOp;
//! use skip_gp::solvers::{block_cg_solve, CgConfig};
//!
//! // SPD system with two right-hand sides.
//! let a = DenseOp(Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]));
//! let b = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 1.0]);
//! let sol = block_cg_solve(&a, &b, CgConfig::default());
//! assert!(sol.columns.iter().all(|c| c.converged));
//! // A·X recovers B.
//! let back = Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]).matmul(&sol.x);
//! assert!(back.max_abs_diff(&b) < 1e-8);
//! ```
//!
//! [`cg_solve`]: super::cg::cg_solve
//! [`Preconditioner::apply_block`]: super::precond::Preconditioner::apply_block

use super::cg::CgConfig;
use super::precond::{build_preconditioner, Preconditioner};
use super::refine::{refined_cg_solve, Precision};
use crate::linalg::{axpy, dot, norm2, Matrix};
use crate::operators::LinearOp;

/// Per-column convergence diagnostics.
#[derive(Clone, Debug)]
pub struct BlockCgColumn {
    /// Iterations this column ran before converging or freezing.
    pub iters: usize,
    /// Final relative preconditioned residual `‖r_j‖_{M⁻¹}/‖b_j‖_{M⁻¹}`
    /// (= `‖r_j‖/‖b_j‖` unpreconditioned).
    pub rel_residual: f64,
    /// Whether this column met [`CgConfig::tol`] against its own
    /// right-hand side's norm.
    pub converged: bool,
}

/// Result of a block-CG solve.
#[derive(Clone, Debug)]
pub struct BlockCgSolution {
    /// n×t solution block, column j solving `A x_j = b_j`.
    pub x: Matrix,
    /// Per-column diagnostics, aligned with the columns of `x`.
    pub columns: Vec<BlockCgColumn>,
    /// Number of block MVMs ([`LinearOp::matmat`] calls) performed — the
    /// batched engine's cost unit; a serial loop would have paid
    /// `Σ_j iters_j` single MVMs instead. Includes the one extra block
    /// MVM a warm start spends on its initial residual.
    pub matmats: usize,
}

impl BlockCgSolution {
    /// True iff every column converged.
    pub fn all_converged(&self) -> bool {
        self.columns.iter().all(|c| c.converged)
    }

    /// Worst relative residual across columns.
    pub fn max_rel_residual(&self) -> f64 {
        self.columns
            .iter()
            .map(|c| c.rel_residual)
            .fold(0.0, f64::max)
    }
}

/// Solve `A X = B` by conjugate gradients, all columns of `B` at once,
/// building the preconditioner [`CgConfig::precond`] describes.
///
/// Runs the standard PCG recurrence per column with the block's MVMs
/// fused into one `matmat` per iteration; converged columns freeze and
/// leave the block. See the module docs for the equivalence guarantee
/// against [`cg_solve`](super::cg::cg_solve), and
/// [`block_cg_solve_with`] for amortized preconditioners and warm
/// starts.
pub fn block_cg_solve(a: &dyn LinearOp, b: &Matrix, cfg: CgConfig) -> BlockCgSolution {
    let m = build_preconditioner(a, None, cfg.precond);
    block_cg_solve_with(a, b, m.as_ref(), None, cfg)
}

/// Block-PCG with an explicit preconditioner and optional warm-start
/// block `x0` (seeding semantics per column as in
/// [`cg_solve_with`](super::cg::cg_solve_with): a column whose seed
/// already meets the tolerance is returned bitwise with 0 iterations).
/// Zero columns of `x0` are cold starts — they cost nothing and don't
/// count as seeded — so a caller can seed one column of a wide block.
/// An `x0` whose shape does not match `b` is ignored.
pub fn block_cg_solve_with(
    a: &dyn LinearOp,
    b: &Matrix,
    m: &dyn Preconditioner,
    x0: Option<&Matrix>,
    cfg: CgConfig,
) -> BlockCgSolution {
    let n = a.dim();
    assert_eq!(b.rows, n, "block_cg: rhs row count must match operator dim");
    assert_eq!(m.dim(), n, "block_cg: preconditioner dim must match operator");
    if cfg.precision == Precision::Mixed {
        return block_refined_solve(a, b, m, x0, cfg);
    }
    let solver = if m.name() == "identity" { "block_cg" } else { "block_pcg" };
    let t = b.cols;
    let x0 = x0.filter(|x| x.rows == n && x.cols == t);
    let g = crate::coordinator::metrics::global();
    let mut matmats = 0usize;

    let nb: Vec<f64> = (0..t).map(|j| norm2(&b.col(j))).collect();
    // Initial iterates and residuals. A zero RHS is solved by x = 0
    // immediately (whatever the seed), and a zero seed column IS a cold
    // start (r₀ = b bitwise) — only the genuinely seeded (nonzero)
    // columns pay for the initial residual, packed into one block MVM of
    // exactly their width (mll_grad seeds 1 y-column next to p cold
    // probes; the probes must not widen the traversal or the metrics).
    let mut xcols: Vec<Vec<f64>> = vec![vec![0.0; n]; t];
    let mut r: Vec<Vec<f64>> = (0..t).map(|j| b.col(j)).collect();
    // The single source of truth for which columns are genuinely seeded:
    // nonzero RHS *and* nonzero seed column.
    let seeded_cols: Vec<usize> = match x0 {
        Some(x0) => (0..t)
            .filter(|&j| nb[j] > 0.0 && norm2(&x0.col(j)) > 0.0)
            .collect(),
        None => Vec::new(),
    };
    let mut is_seeded = vec![false; t];
    for &j in &seeded_cols {
        is_seeded[j] = true;
    }
    if !seeded_cols.is_empty() {
        let x0 = x0.expect("seeded columns imply a seed block");
        let mut xk = Matrix::zeros(n, seeded_cols.len());
        for (c, &j) in seeded_cols.iter().enumerate() {
            xk.set_col(c, &x0.col(j));
        }
        let axk = a.matmat(&xk);
        matmats += 1;
        g.incr("solver.warm.seeded", seeded_cols.len() as u64);
        for (c, &j) in seeded_cols.iter().enumerate() {
            xcols[j] = x0.col(j);
            for (ri, ai) in r[j].iter_mut().zip(&axk.col(c)) {
                *ri -= ai;
            }
        }
    }
    // Preconditioned residuals and per-column reference norms
    // ‖b_j‖_{M⁻¹} — one blocked application each (cold columns reuse
    // their initial rz, which already is bᵀM⁻¹b).
    let mut z: Vec<Vec<f64>> = {
        let mut rb = Matrix::zeros(n, t);
        for (j, rj) in r.iter().enumerate() {
            rb.set_col(j, rj);
        }
        let zb = m.apply_block(&rb);
        (0..t).map(|j| zb.col(j)).collect()
    };
    let mut rz: Vec<f64> = (0..t).map(|j| dot(&r[j], &z[j]).max(0.0)).collect();
    // Cold columns already have ‖b_j‖²_{M⁻¹} in rz (r₀ = b); only the
    // seeded ones need an extra application, packed to their width.
    let mut bnorm_m: Vec<f64> = rz.iter().map(|v| v.sqrt()).collect();
    if !seeded_cols.is_empty() {
        let mut bk = Matrix::zeros(n, seeded_cols.len());
        for (c, &j) in seeded_cols.iter().enumerate() {
            bk.set_col(c, &b.col(j));
        }
        let zb = m.apply_block(&bk);
        for (c, &j) in seeded_cols.iter().enumerate() {
            bnorm_m[j] = dot(&b.col(j), &zb.col(c)).max(0.0).sqrt();
        }
    }
    let bnorm_m = bnorm_m;

    let mut columns: Vec<BlockCgColumn> = (0..t)
        .map(|j| {
            let done = nb[j] == 0.0 || rz[j].sqrt() <= cfg.tol * bnorm_m[j];
            if done && is_seeded[j] {
                g.incr("solver.warm.hit", 1);
            }
            let rel = if nb[j] > 0.0 { rz[j].sqrt() / bnorm_m[j] } else { 0.0 };
            BlockCgColumn { iters: 0, rel_residual: rel, converged: done }
        })
        .collect();
    let mut p = z.clone();
    let mut active: Vec<usize> = (0..t).filter(|&j| !columns[j].converged).collect();

    for _ in 0..cfg.max_iters {
        if active.is_empty() {
            break;
        }
        // One operator traversal for every still-active search direction.
        let mut pk = Matrix::zeros(n, active.len());
        for (c, &j) in active.iter().enumerate() {
            pk.set_col(c, &p[j]);
        }
        let ap = a.matmat(&pk);
        matmats += 1;

        // α/x/r updates per active column.
        let mut advanced = Vec::with_capacity(active.len());
        for (c, &j) in active.iter().enumerate() {
            let apj = ap.col(c);
            let col = &mut columns[j];
            col.iters += 1;
            let pap = dot(&p[j], &apj);
            if pap <= 0.0 {
                // Not PD to working precision — freeze with the current
                // iterate (mirrors cg_solve's bail-out).
                col.rel_residual = rz[j].sqrt() / bnorm_m[j];
                col.converged = col.rel_residual <= cfg.tol;
                continue;
            }
            let alpha = rz[j] / pap;
            axpy(alpha, &p[j], &mut xcols[j]);
            axpy(-alpha, &apj, &mut r[j]);
            advanced.push(j);
        }
        // One blocked preconditioner application for the advanced columns.
        let mut rk = Matrix::zeros(n, advanced.len());
        for (c, &j) in advanced.iter().enumerate() {
            rk.set_col(c, &r[j]);
        }
        let zk = m.apply_block(&rk);
        let mut still = Vec::with_capacity(advanced.len());
        for (c, &j) in advanced.iter().enumerate() {
            z[j] = zk.col(c);
            let rz_new = dot(&r[j], &z[j]).max(0.0);
            let col = &mut columns[j];
            // Convergence against this column's own right-hand side —
            // never the norm of the whole block.
            if rz_new.sqrt() <= cfg.tol * bnorm_m[j] {
                col.rel_residual = rz_new.sqrt() / bnorm_m[j];
                col.converged = true;
                rz[j] = rz_new;
                continue;
            }
            let beta = rz_new / rz[j];
            for (pi, &zi) in p[j].iter_mut().zip(&z[j]) {
                *pi = zi + beta * *pi;
            }
            rz[j] = rz_new;
            still.push(j);
        }
        active = still;
    }
    // Columns that ran out of iterations: report where they stopped.
    for &j in &active {
        columns[j].rel_residual = rz[j].sqrt() / bnorm_m[j];
        columns[j].converged = columns[j].rel_residual <= cfg.tol;
    }

    let mut x = Matrix::zeros(n, t);
    for (j, xc) in xcols.iter().enumerate() {
        x.set_col(j, xc);
    }
    // Per-column solver accounting into the global registry (iterations +
    // convergence failures), plus the block's fused-MVM count.
    for col in &columns {
        crate::coordinator::metrics::record_solver(solver, col.iters, col.converged);
    }
    g.observe(&format!("solver.{solver}.matmats"), matmats as u64);
    BlockCgSolution { x, columns, matmats }
}

/// Mixed-precision block route: iterative refinement has no lockstep
/// block recurrence (each column's outer loop corrects on its own
/// schedule), so [`Precision::Mixed`] solves the columns independently
/// through [`refined_cg_solve`] — every column still meets its own
/// `‖r_j‖_{M⁻¹} ≤ tol·‖b_j‖_{M⁻¹}` certificate. The fused-`matmat`
/// accounting (`matmats`) applies only to the f64 block engine and
/// reports 0 here.
fn block_refined_solve(
    a: &dyn LinearOp,
    b: &Matrix,
    m: &dyn Preconditioner,
    x0: Option<&Matrix>,
    cfg: CgConfig,
) -> BlockCgSolution {
    let n = a.dim();
    let t = b.cols;
    let x0 = x0.filter(|x| x.rows == n && x.cols == t);
    let mut x = Matrix::zeros(n, t);
    let mut columns = Vec::with_capacity(t);
    for j in 0..t {
        let bj = b.col(j);
        // Match the f64 block path's seed semantics: a zero seed column
        // is a cold start, not a warm one.
        let seed = x0.map(|x0| x0.col(j)).filter(|s| norm2(s) > 0.0);
        let sol = refined_cg_solve(a, &bj, m, seed.as_deref(), cfg);
        x.set_col(j, &sol.x);
        columns.push(BlockCgColumn {
            iters: sol.iters,
            rel_residual: sol.rel_residual,
            converged: sol.converged,
        });
    }
    BlockCgSolution { x, columns, matmats: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::solvers::cg::cg_solve;
    use crate::solvers::precond::{IdentityPrecond, PivotedCholeskyPrecond};
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.05);
        a
    }

    #[test]
    fn matches_single_rhs_cg_per_column() {
        let dense = random_spd(40, 1);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(2);
        let b = Matrix::from_fn(40, 5, |_, _| rng.normal());
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        for j in 0..5 {
            let single = cg_solve(&op, &b.col(j), CgConfig::default());
            assert!(single.converged);
            let err = rel_err(&sol.x.col(j), &single.x);
            assert!(err < 1e-10, "col {j}: {err}");
            assert_eq!(sol.columns[j].iters, single.iters, "col {j} iters");
        }
    }

    #[test]
    fn one_matmat_per_joint_iteration() {
        let dense = random_spd(25, 3);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(4);
        let b = Matrix::from_fn(25, 4, |_, _| rng.normal());
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        let max_iters = sol.columns.iter().map(|c| c.iters).max().unwrap();
        assert_eq!(sol.matmats, max_iters);
        let total_single: usize = sol.columns.iter().map(|c| c.iters).sum();
        assert!(sol.matmats < total_single, "block must amortize MVMs");
    }

    #[test]
    fn zero_columns_converge_immediately() {
        let op = DenseOp(Matrix::eye(6));
        let mut b = Matrix::zeros(6, 3);
        b.set(0, 1, 2.0); // only column 1 nonzero
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        assert_eq!(sol.columns[0].iters, 0);
        assert_eq!(sol.columns[2].iters, 0);
        assert_eq!(sol.x.col(0), vec![0.0; 6]);
        assert!((sol.x.get(0, 1) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_difficulty_tracks_per_column() {
        // Column 0 of B is an eigen-direction (converges in 1 iteration);
        // column 1 is generic and needs more.
        let d = Matrix::diag(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let op = DenseOp(d);
        let mut b = Matrix::zeros(5, 2);
        b.set(2, 0, 1.0);
        for i in 0..5 {
            b.set(i, 1, 1.0 + i as f64);
        }
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        assert!(sol.columns[0].iters <= 2);
        assert!(sol.columns[0].iters < sol.columns[1].iters);
    }

    #[test]
    fn respects_max_iters_and_reports_residual() {
        let dense = random_spd(30, 5);
        let op = DenseOp(dense);
        let mut rng = Rng::new(6);
        let b = Matrix::from_fn(30, 2, |_, _| rng.normal());
        let sol =
            block_cg_solve(&op, &b, CgConfig { max_iters: 2, tol: 1e-14, ..Default::default() });
        for c in &sol.columns {
            assert_eq!(c.iters, 2);
            assert!(!c.converged);
            assert!(c.rel_residual > 0.0);
        }
    }

    #[test]
    fn empty_block_is_ok() {
        let op = DenseOp(Matrix::eye(4));
        let sol = block_cg_solve(&op, &Matrix::zeros(4, 0), CgConfig::default());
        assert_eq!(sol.x.cols, 0);
        assert_eq!(sol.matmats, 0);
    }

    #[test]
    fn preconditioned_block_matches_plain_block() {
        let n = 60;
        let mut rng = Rng::new(7);
        let gmat = Matrix::from_fn(n, 8, |_, _| rng.normal());
        let mut dense = gmat.matmul_t(&gmat);
        let noise = 1e-2;
        dense.add_diag(noise);
        let op = DenseOp(dense);
        let b = Matrix::from_fn(n, 3, |_, _| rng.normal());
        let cfg = CgConfig { max_iters: 400, tol: 1e-10, ..Default::default() };
        let plain = block_cg_solve(&op, &b, cfg);
        let m = PivotedCholeskyPrecond::build(&op, 10, Some(noise)).unwrap();
        let pre = block_cg_solve_with(&op, &b, &m, None, cfg);
        assert!(plain.all_converged() && pre.all_converged());
        for j in 0..3 {
            assert!(rel_err(&pre.x.col(j), &plain.x.col(j)) < 1e-8);
            assert!(pre.columns[j].iters <= plain.columns[j].iters);
        }
    }

    #[test]
    fn warm_started_block_returns_seeds_bitwise() {
        let dense = random_spd(20, 8);
        let op = DenseOp(dense);
        let mut rng = Rng::new(9);
        let b = Matrix::from_fn(20, 3, |_, _| rng.normal());
        // Seed from a solve two digits tighter than the warm solve's
        // tolerance, so every seed sits squarely inside it.
        let cold = block_cg_solve(
            &op,
            &b,
            CgConfig { max_iters: 500, tol: 1e-10, ..Default::default() },
        );
        assert!(cold.all_converged());
        let m = IdentityPrecond::new(20);
        let warm = block_cg_solve_with(&op, &b, &m, Some(&cold.x), CgConfig::default());
        assert!(warm.all_converged());
        assert_eq!(warm.x.data, cold.x.data);
        assert!(warm.columns.iter().all(|c| c.iters == 0));
        // Only the one initial-residual block MVM was paid.
        assert_eq!(warm.matmats, 1);
    }
}
