//! Block conjugate gradients: solve `A X = B` for t right-hand sides with
//! one operator traversal per iteration.
//!
//! The paper's inference loop needs many simultaneous solves against the
//! same `K̂` — the predictive solve `α = K̂⁻¹y` next to the Hutchinson
//! trace probes `K̂⁻¹zᵢ` of the gradient (§2.2), or a batch of test-time
//! solves. Serial CG pays the operator once *per RHS per iteration*; for
//! SKIP that is t separate O(r²n) Lemma-3.1 contractions whose memory
//! traffic dominates. This solver runs the t standard CG recurrences in
//! lockstep and fuses their MVMs into a single [`LinearOp::matmat`] call,
//! so the structured operator amortizes its traversal across the block
//! (fused contraction, paired FFTs, shared stencil decode — see each
//! operator's `matmat`).
//!
//! Columns are tracked independently: each has its own α/β scalars,
//! residual, and iteration count, and a column that converges (or hits a
//! non-PD breakdown) is frozen and dropped from subsequent block MVMs.
//! With an exact `matmat` (one that matches column-wise `matvec`, which
//! every fast path in this crate does to rounding), the per-column
//! iterates are identical to t independent [`cg_solve`] runs — verified
//! by the `matmat_props` property tests to 1e-8 and tighter.
//!
//! ```
//! use skip_gp::linalg::Matrix;
//! use skip_gp::operators::DenseOp;
//! use skip_gp::solvers::{block_cg_solve, CgConfig};
//!
//! // SPD system with two right-hand sides.
//! let a = DenseOp(Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]));
//! let b = Matrix::from_vec(2, 2, vec![1.0, 2.0, 0.0, 1.0]);
//! let sol = block_cg_solve(&a, &b, CgConfig::default());
//! assert!(sol.columns.iter().all(|c| c.converged));
//! // A·X recovers B.
//! let back = Matrix::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]).matmul(&sol.x);
//! assert!(back.max_abs_diff(&b) < 1e-8);
//! ```
//!
//! [`cg_solve`]: super::cg::cg_solve

use super::cg::CgConfig;
use crate::linalg::{axpy, dot, norm2, Matrix};
use crate::operators::LinearOp;

/// Per-column convergence diagnostics.
#[derive(Clone, Debug)]
pub struct BlockCgColumn {
    /// Iterations this column ran before converging or freezing.
    pub iters: usize,
    /// Final relative residual ‖r‖/‖b‖.
    pub rel_residual: f64,
    pub converged: bool,
}

/// Result of a block-CG solve.
#[derive(Clone, Debug)]
pub struct BlockCgSolution {
    /// n×t solution block, column j solving `A x_j = b_j`.
    pub x: Matrix,
    /// Per-column diagnostics, aligned with the columns of `x`.
    pub columns: Vec<BlockCgColumn>,
    /// Number of block MVMs ([`LinearOp::matmat`] calls) performed — the
    /// batched engine's cost unit; a serial loop would have paid
    /// `Σ_j iters_j` single MVMs instead.
    pub matmats: usize,
}

impl BlockCgSolution {
    /// True iff every column converged.
    pub fn all_converged(&self) -> bool {
        self.columns.iter().all(|c| c.converged)
    }

    /// Worst relative residual across columns.
    pub fn max_rel_residual(&self) -> f64 {
        self.columns
            .iter()
            .map(|c| c.rel_residual)
            .fold(0.0, f64::max)
    }
}

/// Solve `A X = B` by conjugate gradients, all columns of `B` at once.
///
/// Runs the standard CG recurrence per column with the block's MVMs fused
/// into one `matmat` per iteration; converged columns freeze and leave
/// the block. See the module docs for the equivalence guarantee against
/// [`cg_solve`](super::cg::cg_solve).
pub fn block_cg_solve(a: &dyn LinearOp, b: &Matrix, cfg: CgConfig) -> BlockCgSolution {
    let n = a.dim();
    assert_eq!(b.rows, n, "block_cg: rhs row count must match operator dim");
    let t = b.cols;
    let mut xcols: Vec<Vec<f64>> = vec![vec![0.0; n]; t];
    let mut r: Vec<Vec<f64>> = (0..t).map(|j| b.col(j)).collect();
    let mut p = r.clone();
    let nb: Vec<f64> = r.iter().map(|c| norm2(c)).collect();
    let mut rs_old: Vec<f64> = r.iter().map(|c| dot(c, c)).collect();
    let mut columns: Vec<BlockCgColumn> = nb
        .iter()
        .map(|&nbj| BlockCgColumn {
            iters: 0,
            rel_residual: 0.0,
            // A zero RHS is solved by x = 0 immediately.
            converged: nbj == 0.0,
        })
        .collect();
    let mut active: Vec<usize> = (0..t).filter(|&j| nb[j] > 0.0).collect();
    let mut matmats = 0usize;

    for _ in 0..cfg.max_iters {
        if active.is_empty() {
            break;
        }
        // One operator traversal for every still-active search direction.
        let mut pk = Matrix::zeros(n, active.len());
        for (c, &j) in active.iter().enumerate() {
            pk.set_col(c, &p[j]);
        }
        let ap = a.matmat(&pk);
        matmats += 1;

        let mut still = Vec::with_capacity(active.len());
        for (c, &j) in active.iter().enumerate() {
            let apj = ap.col(c);
            let col = &mut columns[j];
            col.iters += 1;
            let pap = dot(&p[j], &apj);
            if pap <= 0.0 {
                // Not PD to working precision — freeze with the current
                // iterate (mirrors cg_solve's bail-out).
                col.rel_residual = rs_old[j].sqrt() / nb[j];
                col.converged = col.rel_residual <= cfg.tol;
                continue;
            }
            let alpha = rs_old[j] / pap;
            axpy(alpha, &p[j], &mut xcols[j]);
            axpy(-alpha, &apj, &mut r[j]);
            let rs_new = dot(&r[j], &r[j]);
            if rs_new.sqrt() <= cfg.tol * nb[j] {
                col.rel_residual = rs_new.sqrt() / nb[j];
                col.converged = true;
                rs_old[j] = rs_new;
                continue;
            }
            let beta = rs_new / rs_old[j];
            for (pi, &ri) in p[j].iter_mut().zip(&r[j]) {
                *pi = ri + beta * *pi;
            }
            rs_old[j] = rs_new;
            still.push(j);
        }
        active = still;
    }
    // Columns that ran out of iterations: report where they stopped.
    for &j in &active {
        columns[j].rel_residual = rs_old[j].sqrt() / nb[j];
        columns[j].converged = columns[j].rel_residual <= cfg.tol;
    }

    let mut x = Matrix::zeros(n, t);
    for (j, xc) in xcols.iter().enumerate() {
        x.set_col(j, xc);
    }
    // Per-column solver accounting into the global registry (iterations +
    // convergence failures), plus the block's fused-MVM count.
    for col in &columns {
        crate::coordinator::metrics::record_solver("block_cg", col.iters, col.converged);
    }
    crate::coordinator::metrics::global().observe("solver.block_cg.matmats", matmats as u64);
    BlockCgSolution { x, columns, matmats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::operators::DenseOp;
    use crate::solvers::cg::cg_solve;
    use crate::util::{rel_err, Rng};

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let b = Matrix::from_fn(n, n, |_, _| rng.normal());
        let mut a = b.matmul_t(&b);
        a.add_diag(n as f64 * 0.05);
        a
    }

    #[test]
    fn matches_single_rhs_cg_per_column() {
        let dense = random_spd(40, 1);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(2);
        let b = Matrix::from_fn(40, 5, |_, _| rng.normal());
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        for j in 0..5 {
            let single = cg_solve(&op, &b.col(j), CgConfig::default());
            assert!(single.converged);
            let err = rel_err(&sol.x.col(j), &single.x);
            assert!(err < 1e-10, "col {j}: {err}");
            assert_eq!(sol.columns[j].iters, single.iters, "col {j} iters");
        }
    }

    #[test]
    fn one_matmat_per_joint_iteration() {
        let dense = random_spd(25, 3);
        let op = DenseOp(dense.clone());
        let mut rng = Rng::new(4);
        let b = Matrix::from_fn(25, 4, |_, _| rng.normal());
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        let max_iters = sol.columns.iter().map(|c| c.iters).max().unwrap();
        assert_eq!(sol.matmats, max_iters);
        let total_single: usize = sol.columns.iter().map(|c| c.iters).sum();
        assert!(sol.matmats < total_single, "block must amortize MVMs");
    }

    #[test]
    fn zero_columns_converge_immediately() {
        let op = DenseOp(Matrix::eye(6));
        let mut b = Matrix::zeros(6, 3);
        b.set(0, 1, 2.0); // only column 1 nonzero
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        assert_eq!(sol.columns[0].iters, 0);
        assert_eq!(sol.columns[2].iters, 0);
        assert_eq!(sol.x.col(0), vec![0.0; 6]);
        assert!((sol.x.get(0, 1) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_difficulty_tracks_per_column() {
        // Column 0 of B is an eigen-direction (converges in 1 iteration);
        // column 1 is generic and needs more.
        let d = Matrix::diag(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let op = DenseOp(d);
        let mut b = Matrix::zeros(5, 2);
        b.set(2, 0, 1.0);
        for i in 0..5 {
            b.set(i, 1, 1.0 + i as f64);
        }
        let sol = block_cg_solve(&op, &b, CgConfig::default());
        assert!(sol.all_converged());
        assert!(sol.columns[0].iters <= 2);
        assert!(sol.columns[0].iters < sol.columns[1].iters);
    }

    #[test]
    fn respects_max_iters_and_reports_residual() {
        let dense = random_spd(30, 5);
        let op = DenseOp(dense);
        let mut rng = Rng::new(6);
        let b = Matrix::from_fn(30, 2, |_, _| rng.normal());
        let sol = block_cg_solve(&op, &b, CgConfig { max_iters: 2, tol: 1e-14 });
        for c in &sol.columns {
            assert_eq!(c.iters, 2);
            assert!(!c.converged);
            assert!(c.rel_residual > 0.0);
        }
    }

    #[test]
    fn empty_block_is_ok() {
        let op = DenseOp(Matrix::eye(4));
        let sol = block_cg_solve(&op, &Matrix::zeros(4, 0), CgConfig::default());
        assert_eq!(sol.x.cols, 0);
        assert_eq!(sol.matmats, 0);
    }
}
