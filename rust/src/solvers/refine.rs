//! Mixed-precision iterative refinement for MVM solves.
//!
//! The MVM bottleneck of SKI/SKIP inference is memory bandwidth: the
//! stencil weights, Toeplitz spectra, and Gram bands stream through the
//! cache once per CG iteration. Storing them in f32 halves that traffic —
//! but raw f32 CG cannot certify the tolerances GP training asks for
//! (attainable relative residual scales like `eps32 · κ(A)`, which for a
//! small-noise covariance is ≥ 1). Classic iterative refinement squares
//! that circle:
//!
//! 1. **inner**: solve `A d ≈ r` in f32 arithmetic against the operator's
//!    f32 mirror ([`crate::operators::LinearOpF32`]), preconditioned by
//!    the caller's f64 preconditioner (applied through conversion — this
//!    collapses the condition number the f32 recurrence sees);
//! 2. **outer**: in f64, update `x += d`, recompute the *true* residual
//!    `r = b − A x` with the f64 operator, and test the same
//!    `‖r‖_{M⁻¹} ≤ tol · ‖b‖_{M⁻¹}` certificate the f64 path pins.
//!
//! Each outer sweep multiplies the residual by roughly the inner solve's
//! relative tolerance, so a handful of sweeps reach f64-grade tolerances
//! while every hot MVM runs at f32 bandwidth. If the inner solve stalls
//! (residual stops contracting — pathological conditioning the
//! preconditioner did not capture), the solve falls back to plain f64 CG
//! seeded with the current iterate, so the certificate holds
//! unconditionally.
//!
//! Entry is by configuration, not call site: [`Precision::Mixed`] on
//! [`CgConfig`] routes [`super::cg_solve_with`],
//! [`super::block_cg_solve_with`], and the grid-space solver through this
//! module; [`Precision::F64`] (the default) leaves the historical path
//! bitwise untouched.

use super::cg::{cg_solve_f64, CgConfig, CgSolution};
use super::precond::Preconditioner;
use crate::linalg::{axpy, dot, norm2};
use crate::operators::{LinearOp, LinearOpF32};

/// Arithmetic policy for iterative solves (`--precision` on the CLI).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Pure f64 — the historical path, bitwise unchanged.
    #[default]
    F64,
    /// f32 operator storage with an f64-refined outer loop; meets the
    /// same residual certificate as [`Precision::F64`] (falls back to
    /// f64 CG when the operator has no f32 mirror or the inner solve
    /// stalls).
    Mixed,
}

impl Precision {
    /// Parse a CLI/config token (`f64`/`double`, `mixed`/`f32`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" | "double" => Some(Precision::F64),
            "mixed" | "f32" => Some(Precision::Mixed),
            _ => None,
        }
    }

    /// Canonical token, mirror of [`Precision::parse`].
    pub fn describe(&self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::Mixed => "mixed",
        }
    }
}

/// Outer refinement sweeps before declaring a stall. Each sweep contracts
/// the residual by ~[`INNER_TOL`], so certified tolerances down to
/// ~1e-12 need 3-4 sweeps; hitting this cap means the inner solver is
/// not converging and the f64 fallback takes over.
pub(crate) const MAX_OUTER: usize = 10;

/// Relative tolerance of the inner f32 solve — loose on purpose: a few
/// digits per sweep is the efficient operating point of refinement, and
/// f32 cannot certify much tighter anyway.
pub(crate) const INNER_TOL: f64 = 1e-4;

/// Minimum factor the preconditioned residual must shrink by per outer
/// sweep; anything less is a stall.
pub(crate) const MIN_CONTRACTION: f64 = 0.5;

/// f64-accumulated dot product of two f32 vectors — the accuracy anchor
/// of the inner recurrence (f32 dot products lose ~`√n` ulps, enough to
/// destabilize CG scalars at n = 10⁵⁺).
pub(crate) fn dot32(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

pub(crate) fn to_f32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

pub(crate) fn to_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

/// Apply the f64 preconditioner to an f32 vector (convert → apply →
/// convert). The extra f64 work here is per-*vector*, not per-operator
/// entry, so it does not erode the bandwidth win.
fn precond_f32(m: &dyn Preconditioner, r: &[f32]) -> Vec<f32> {
    to_f32(&m.apply(&to_f64(r)))
}

/// Inner preconditioned CG in f32 arithmetic: solves `A d ≈ r` to
/// [`INNER_TOL`] with f64-accumulated scalars. Returns the correction in
/// f64 plus the iteration count. Never consulted for a certificate —
/// only the outer f64 residual is.
fn inner_pcg_f32(
    a32: &dyn LinearOpF32,
    m: &dyn Preconditioner,
    r: &[f64],
    max_iters: usize,
) -> (Vec<f64>, usize) {
    let n = a32.dim();
    let rf = to_f32(r);
    let mut x = vec![0.0f32; n];
    let mut resid = rf;
    let mut z = precond_f32(m, &resid);
    let mut rz = dot32(&resid, &z).max(0.0);
    let bnorm = rz.sqrt();
    if bnorm == 0.0 || !bnorm.is_finite() {
        return (to_f64(&x), 0);
    }
    let mut p = z.clone();
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        let ap = a32.matvec_f32(&p);
        let pap = dot32(&p, &ap);
        if pap.is_nan() || pap <= 0.0 {
            // Indefinite to f32 precision (or NaN) — stop with the
            // current correction; the outer loop decides what it earned.
            break;
        }
        let alpha = (rz / pap) as f32;
        for (xi, &pi) in x.iter_mut().zip(&p) {
            *xi += alpha * pi;
        }
        for (ri, &api) in resid.iter_mut().zip(&ap) {
            *ri -= alpha * api;
        }
        z = precond_f32(m, &resid);
        let rz_new = dot32(&resid, &z).max(0.0);
        if rz_new.sqrt() <= INNER_TOL * bnorm {
            break;
        }
        let beta = (rz_new / rz) as f32;
        for (pi, &zi) in p.iter_mut().zip(&z) {
            *pi = zi + beta * *pi;
        }
        rz = rz_new;
    }
    (to_f64(&x), iters)
}

/// Solve `A x = b` by mixed-precision iterative refinement, meeting the
/// same preconditioned-residual certificate as
/// [`cg_solve_with`](super::cg_solve_with):
/// `‖b − A x‖_{M⁻¹} ≤ tol · ‖b‖_{M⁻¹}`, measured with the f64 operator.
///
/// Routing rules match the f64 path: a zero right-hand side returns
/// immediately; a warm-start seed already inside the tolerance is
/// returned **bitwise unchanged** with `iters == 0`. Operators without an
/// f32 mirror ([`LinearOp::as_f32`] = `None`) and inner-solve stalls fall
/// back to [`cg_solve_f64`] (seeded with the current iterate), counted
/// under `solver.refine.fallback.*`.
///
/// Metrics: `solver.refine.iters` (inner f32 iterations, via
/// `record_solver`), `solver.refine.sweeps` (outer corrections),
/// `solver.refine.rel_residual_neg_log10` (achieved certificate).
pub fn refined_cg_solve(
    a: &dyn LinearOp,
    b: &[f64],
    m: &dyn Preconditioner,
    x0: Option<&[f64]>,
    cfg: CgConfig,
) -> CgSolution {
    let n = a.dim();
    assert_eq!(b.len(), n);
    assert_eq!(m.dim(), n, "preconditioner dimension must match operator");
    let g = crate::coordinator::metrics::global();
    let a32 = match a.as_f32() {
        Some(view) => view,
        None => {
            // No f32 mirror anywhere in the operator composition — run
            // the solve the classic way and say so in the metrics.
            g.incr("solver.refine.fallback.no_f32", 1);
            return cg_solve_f64(a, b, m, x0, cfg);
        }
    };
    let nb = norm2(b);
    if nb == 0.0 {
        crate::coordinator::metrics::record_solver("refine", 0, true);
        return CgSolution { x: vec![0.0; n], iters: 0, rel_residual: 0.0, converged: true };
    }
    let zb = m.apply(b);
    let bnorm_m = dot(b, &zb).max(0.0).sqrt();
    let x0 = x0.filter(|x| x.len() == n);
    let seeded = x0.is_some();
    let (mut x, mut r) = match x0 {
        Some(x0) => {
            let ax = a.matvec(x0);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
            (x0.to_vec(), r)
        }
        None => (vec![0.0; n], b.to_vec()),
    };
    if seeded {
        g.incr("solver.warm.seeded", 1);
    }
    let rnorm_of = |r: &[f64]| {
        let z = m.apply(r);
        dot(r, &z).max(0.0).sqrt()
    };
    let mut rnorm = rnorm_of(&r);
    let threshold = cfg.tol * bnorm_m;
    if rnorm <= threshold {
        // Zero sweeps: cold zero-seed with an easy b, or a warm seed
        // already inside the tolerance (returned bitwise, matching the
        // f64 path's warm-start guarantee).
        if seeded {
            g.incr("solver.warm.hit", 1);
        }
        crate::coordinator::metrics::record_solver("refine", 0, true);
        let rel = if bnorm_m > 0.0 { rnorm / bnorm_m } else { 0.0 };
        return CgSolution { x, iters: 0, rel_residual: rel, converged: true };
    }
    let mut inner_total = 0usize;
    let mut sweeps = 0usize;
    let mut converged = false;
    for _ in 0..MAX_OUTER {
        sweeps += 1;
        let (d, it) = inner_pcg_f32(a32.as_ref(), m, &r, cfg.max_iters);
        inner_total += it;
        axpy(1.0, &d, &mut x);
        // True residual, f64 operator: refinement certifies on this, not
        // on anything the f32 recurrence believes.
        let ax = a.matvec(&x);
        for ((ri, &bi), &axi) in r.iter_mut().zip(b).zip(&ax) {
            *ri = bi - axi;
        }
        let rnorm_new = rnorm_of(&r);
        if rnorm_new <= threshold {
            rnorm = rnorm_new;
            converged = true;
            break;
        }
        if !rnorm_new.is_finite() || rnorm_new > MIN_CONTRACTION * rnorm {
            // Stalled: the f32 inner solve is no longer contracting the
            // f64 residual. Hand the current iterate to f64 CG, which
            // certifies unconditionally.
            g.incr("solver.refine.fallback.stall", 1);
            g.incr("solver.refine.sweeps", sweeps as u64);
            crate::coordinator::metrics::record_solver("refine", inner_total, false);
            let seed = if rnorm_new.is_finite() && rnorm_new < rnorm { Some(&x[..]) } else { x0 };
            return cg_solve_f64(a, b, m, seed, cfg);
        }
        rnorm = rnorm_new;
    }
    if !converged {
        // Out of sweeps — certify with f64 CG from the refined iterate.
        g.incr("solver.refine.fallback.sweep_budget", 1);
        g.incr("solver.refine.sweeps", sweeps as u64);
        crate::coordinator::metrics::record_solver("refine", inner_total, false);
        return cg_solve_f64(a, b, m, Some(&x), cfg);
    }
    let rel = if bnorm_m > 0.0 { rnorm / bnorm_m } else { 0.0 };
    g.incr("solver.refine.sweeps", sweeps as u64);
    if rel > 0.0 {
        g.observe("solver.refine.rel_residual_neg_log10", (-rel.log10()).max(0.0) as u64);
    }
    crate::coordinator::metrics::record_solver("refine", inner_total, true);
    CgSolution { x, iters: inner_total, rel_residual: rel, converged: true }
}

/// Raw unpreconditioned f32 CG — **diagnostic only**. This is the solver
/// refinement exists to avoid: its attainable residual floors out near
/// `eps32 · κ(A)`, so on small-noise covariances it stalls far above any
/// useful tolerance (the property tests pin exactly that). Returns `None`
/// when the operator has no f32 mirror. The reported `rel_residual` is
/// the *true* f64 relative residual `‖b − A x‖/‖b‖`.
pub fn raw_cg_f32(a: &dyn LinearOp, b: &[f64], cfg: CgConfig) -> Option<CgSolution> {
    let a32 = a.as_f32()?;
    let n = a.dim();
    assert_eq!(b.len(), n);
    let bf = to_f32(b);
    let mut x = vec![0.0f32; n];
    let mut r = bf;
    let mut rz = dot32(&r, &r).max(0.0);
    let bnorm = rz.sqrt();
    let mut iters = 0;
    if bnorm > 0.0 {
        let mut p = r.clone();
        for _ in 0..cfg.max_iters {
            iters += 1;
            let ap = a32.matvec_f32(&p);
            let pap = dot32(&p, &ap);
            if pap.is_nan() || pap <= 0.0 {
                break;
            }
            let alpha = (rz / pap) as f32;
            for (xi, &pi) in x.iter_mut().zip(&p) {
                *xi += alpha * pi;
            }
            for (ri, &api) in r.iter_mut().zip(&ap) {
                *ri -= alpha * api;
            }
            let rz_new = dot32(&r, &r).max(0.0);
            if rz_new.sqrt() <= cfg.tol * bnorm {
                break;
            }
            let beta = (rz_new / rz) as f32;
            for (pi, &ri) in p.iter_mut().zip(&r) {
                *pi = ri + beta * *pi;
            }
            rz = rz_new;
        }
    }
    let xd = to_f64(&x);
    let ax = a.matvec(&xd);
    let resid: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
    let nb = norm2(b);
    let rel = if nb > 0.0 { norm2(&resid) / nb } else { 0.0 };
    Some(CgSolution { x: xd, iters, rel_residual: rel, converged: rel <= cfg.tol })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::operators::DenseOp;
    use crate::solvers::cg::cg_solve;
    use crate::solvers::precond::IdentityPrecond;
    use crate::util::{rel_err, Rng};

    fn spd(n: usize, noise: f64, seed: u64) -> DenseOp {
        let mut rng = Rng::new(seed);
        let g = Matrix::from_fn(n, 8, |_, _| rng.normal());
        let mut a = g.matmul_t(&g);
        a.add_diag(noise);
        DenseOp(a)
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F64, Precision::Mixed] {
            assert_eq!(Precision::parse(p.describe()), Some(p));
        }
        assert_eq!(Precision::parse("f32"), Some(Precision::Mixed));
        assert_eq!(Precision::parse("double"), Some(Precision::F64));
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Precision::default(), Precision::F64);
    }

    #[test]
    fn refined_matches_f64_cg_to_certificate() {
        let op = spd(60, 1e-2, 1);
        let mut rng = Rng::new(2);
        let b = rng.normal_vec(60);
        let cfg = CgConfig { max_iters: 500, tol: 1e-10, ..Default::default() };
        let m = IdentityPrecond::new(60);
        let gold = cg_solve(&op, &b, cfg);
        let mixed = refined_cg_solve(&op, &b, &m, None, cfg);
        assert!(gold.converged && mixed.converged, "rel {}", mixed.rel_residual);
        assert!(mixed.rel_residual <= 1e-10);
        assert!(rel_err(&mixed.x, &gold.x) < 1e-8, "{}", rel_err(&mixed.x, &gold.x));
    }

    #[test]
    fn warm_seed_inside_tolerance_returns_bitwise() {
        let op = spd(40, 1e-2, 3);
        let mut rng = Rng::new(4);
        let b = rng.normal_vec(40);
        let tight = CgConfig { max_iters: 500, tol: 1e-12, ..Default::default() };
        let m = IdentityPrecond::new(40);
        let cold = refined_cg_solve(&op, &b, &m, None, tight);
        assert!(cold.converged);
        let loose = CgConfig { max_iters: 500, tol: 1e-8, ..Default::default() };
        let warm = refined_cg_solve(&op, &b, &m, Some(&cold.x), loose);
        assert_eq!(warm.iters, 0);
        assert_eq!(warm.x, cold.x);
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let op = spd(10, 1e-2, 5);
        let m = IdentityPrecond::new(10);
        let sol = refined_cg_solve(&op, &[0.0; 10], &m, None, CgConfig::default());
        assert!(sol.converged);
        assert_eq!(sol.x, vec![0.0; 10]);
        assert_eq!(sol.iters, 0);
    }

    #[test]
    fn raw_f32_cg_reports_true_residual() {
        let op = spd(50, 1.0, 6);
        let mut rng = Rng::new(7);
        let b = rng.normal_vec(50);
        let cfg = CgConfig { max_iters: 300, tol: 1e-6, ..Default::default() };
        let sol = raw_cg_f32(&op, &b, cfg).expect("dense has an f32 mirror");
        // Well conditioned (unit noise): f32 CG gets within f32 range.
        assert!(sol.rel_residual < 1e-3, "rel {}", sol.rel_residual);
    }
}
