//! Multi-task serving/streaming bench: per-task predict throughput and
//! online task-enrollment latency across a task-count sweep
//! (T ∈ {4, 64} in the `--fast` CI smoke, plus T = 1024 in the full
//! run), emitting machine-readable `results/BENCH_mtgp.json`. CI's
//! `tools/bench_check` gates the enrollment-vs-rebuild speedup — the
//! one machine-portable ratio — against its checked-in floor.
//!
//! Run: `cargo bench --bench bench_mtgp` (add `-- --fast` in CI smoke).

#![allow(clippy::needless_range_loop)] // index-heavy numeric bench loops

use skip_gp::gp::GpHypers;
use skip_gp::grid::Grid1d;
use skip_gp::kernels::TaskKernel;
use skip_gp::linalg::Matrix;
use skip_gp::serve::{ServeEngine, VarianceMode};
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{Rng, Timer};
use std::io::Write;
use std::path::Path;

fn quantile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i] * 1e6
}

struct SweepResult {
    tasks: usize,
    n: usize,
    build_ms: f64,
    predict_qps: f64,
    enroll_p50_us: f64,
    enroll_p99_us: f64,
}

fn run_case(tasks: usize, per_task: usize, rng: &mut Rng) -> SweepResult {
    let d = 2;
    let n = tasks * per_task;
    let mut data = Vec::with_capacity(n * d);
    let mut ys = Vec::with_capacity(n);
    let mut task_of = Vec::with_capacity(n);
    for t in 0..tasks {
        let sign = if t % 2 == 0 { 1.0 } else { -1.0 };
        for _ in 0..per_task {
            let x0 = rng.uniform_in(-0.95, 0.95);
            let x1 = rng.uniform_in(-0.95, 0.95);
            data.push(x0);
            data.push(x1);
            ys.push(sign * ((2.0 * x0).sin() + (3.0 * x1).cos()) + 0.05 * rng.normal());
            task_of.push(t);
        }
    }
    let xs = Matrix::from_vec(n, d, data);
    let b = Matrix::from_fn(tasks, 2, |_, _| 0.1 * rng.normal());
    let kernel = TaskKernel::new(b, vec![0.5; tasks]);
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, 16).unwrap(),
        Grid1d::fit(-1.0, 1.0, 16).unwrap(),
    ];
    let cg = CgConfig { max_iters: 300, tol: 1e-8, ..Default::default() };
    // Serving-shaped config: the variance factor is built once (rank-16
    // Lanczos) at construction, and the drift budget keeps measured
    // enrollments on the warm incremental path (mean caches patched,
    // variance deferred) — the latency a serving fleet actually pays
    // per online enrollment.
    let cfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        log_capacity: 1 << 16,
        variance: VarianceMode::Lanczos(16),
        patch_eps: 1e-12,
        ..Default::default()
    };
    // σ_n² = 0.3 keeps the Hadamard systems well-conditioned across the
    // whole sweep (T = 1024 included), so iteration counts stay flat.
    let h = GpHypers::new(0.6, 1.0, 0.3);

    let t0 = Timer::start();
    let mut live =
        IncrementalState::new_multitask(xs, ys, (kernel, task_of), h, axes, cg, cfg)
            .expect("multi-task live state");
    let build_ms = t0.elapsed_s() * 1e3;

    // Per-task predict throughput through the serving engine (the same
    // par_map path the request batcher dispatches to), tasks cycling
    // across the whole range.
    let engine = ServeEngine::new(live.to_snapshot()).expect("engine");
    let q_rows = 256;
    let qx = Matrix::from_fn(q_rows, d, |_, _| rng.uniform_in(-0.9, 0.9));
    let qtasks: Vec<usize> = (0..q_rows).map(|i| i % tasks).collect();
    let repeats = 8;
    let t0 = Timer::start();
    for _ in 0..repeats {
        let (mean, _var) = engine.predict_tasks(&qx, &qtasks);
        assert!(mean.iter().all(|m| m.is_finite()));
    }
    let predict_qps = (q_rows * repeats) as f64 / t0.elapsed_s().max(1e-12);

    // Online enrollment latency: each ingest names task == num_tasks,
    // growing the model by one task (decoupled B row, warm re-solve,
    // patched caches).
    let enrolls = 8;
    let mut enroll_s = Vec::with_capacity(enrolls);
    for e in 0..enrolls {
        let x = vec![rng.uniform_in(-0.9, 0.9), rng.uniform_in(-0.9, 0.9)];
        let y = rng.normal();
        let xm = Matrix::from_vec(1, d, x);
        let t0 = Timer::start();
        let report = live.ingest_block_tasks(&xm, &[y], &[tasks + e]).expect("enroll");
        enroll_s.push(t0.elapsed_s());
        assert_eq!(report.enrolled, 1, "each bench ingest must enroll");
    }
    enroll_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let enroll_p50_us = quantile_us(&enroll_s, 0.50);
    let enroll_p99_us = quantile_us(&enroll_s, 0.99);

    println!(
        "T={tasks:>5}  n={n:>5}  build {build_ms:>9.2}ms   predict {predict_qps:>9.0} q/s   \
         enroll p50 {enroll_p50_us:>9.1}µs  p99 {enroll_p99_us:>9.1}µs"
    );
    SweepResult { tasks, n, build_ms, predict_qps, enroll_p50_us, enroll_p99_us }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    // (T, rows per task): n grows sublinearly in T so the full sweep
    // stays minutes, not hours.
    let mut sweep: Vec<(usize, usize)> = vec![(4, 64), (64, 8)];
    if !fast {
        sweep.push((1024, 2));
    }
    let mut rng = Rng::new(0);
    let results: Vec<SweepResult> =
        sweep.iter().map(|&(t, p)| run_case(t, p, &mut rng)).collect();

    // The gated ratio comes from the smallest case — the one every run
    // (fast and full, any machine) measures.
    let base = &results[0];
    let speedup = base.build_ms * 1e3 / base.enroll_p50_us.max(1e-9);
    println!(
        "  -> online enrollment is {speedup:.2}x cheaper than a cold multi-task rebuild (T={})",
        base.tasks
    );

    let mut entries = String::new();
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            entries.push_str(",\n");
        }
        entries.push_str(&format!(
            "    {{\"tasks\": {}, \"n\": {}, \"build_ms\": {:.3}, \"predict_qps\": {:.1}, \
             \"enroll_p50_us\": {:.2}, \"enroll_p99_us\": {:.2}}}",
            r.tasks, r.n, r.build_ms, r.predict_qps, r.enroll_p50_us, r.enroll_p99_us
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"mtgp\",\n  \"fast\": {fast},\n  \"sweep\": [\n{entries}\n  ],\n  \
         \"speedup_enroll_vs_rebuild\": {speedup:.3}\n}}\n"
    );
    let path = Path::new("results/BENCH_mtgp.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut out = std::fs::File::create(path).expect("bench json");
    out.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());

    assert!(
        speedup >= 2.0,
        "acceptance: online enrollment must be ≥2x cheaper than a cold \
         multi-task rebuild (got {speedup:.2}x)"
    );
}
