//! Inducing-grid bench: dense Kronecker tensor grids vs the
//! combination-technique sparse grid, across dimensionality.
//!
//! For each d the bench builds the SKI covariance operator both ways
//! (where the dense mᵈ grid is feasible at all) and records grid point
//! counts, operator build time, and MVM time into machine-readable
//! `results/BENCH_grid.json` — the curse-of-dimensionality picture in
//! numbers: dense cells grow as mᵈ while sparse points grow
//! near-linearly in d.
//!
//! Run: `cargo bench --bench bench_grid` (add `-- --fast` in CI smoke).

#![allow(clippy::needless_range_loop)]

use skip_gp::grid::{grid_ski_operator, GridSpec, InducingGrid, RectilinearGrid, SparseGrid};
use skip_gp::kernels::ProductKernel;
use skip_gp::linalg::Matrix;
use skip_gp::operators::LinearOp;
use skip_gp::util::{bench_median_s, Rng, Timer};
use std::io::Write;
use std::path::Path;

struct SideStats {
    points: usize,
    build_s: f64,
    mvm_s: f64,
}

fn json_side(s: &SideStats) -> String {
    format!(
        "{{\"points\": {}, \"build_s\": {:.6}, \"mvm_s\": {:.6}}}",
        s.points, s.build_s, s.mvm_s
    )
}

fn measure(xs: &Matrix, kern: &ProductKernel, grid: &dyn InducingGrid) -> SideStats {
    let t = Timer::start();
    let op = grid_ski_operator(xs, kern, grid);
    let build_s = t.elapsed_s();
    let mut rng = Rng::new(99);
    let v = rng.normal_vec(xs.rows);
    let mvm_s = bench_median_s(5, 0.02, || {
        std::hint::black_box(op.matvec(std::hint::black_box(&v)));
    });
    SideStats { points: grid.total_points(), build_s, mvm_s }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let n = if fast { 600 } else { 2000 };
    // (d, dense m per dim or 0 = infeasible, sparse level)
    let mut cases: Vec<(usize, usize, usize)> = vec![(2, 32, 5), (3, 20, 4), (8, 0, 3)];
    if !fast {
        cases.push((10, 0, 3));
    }

    let mut rows = Vec::new();
    for &(d, dense_m, level) in &cases {
        let mut rng = Rng::new(7 + d as u64);
        let xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
        let kern = ProductKernel::rbf(d, (2.0 * d as f64 / 3.0).sqrt(), 1.0);

        let sparse_grid = SparseGrid::fit(&xs, level).expect("sparse grid fit");
        let n_terms = sparse_grid.terms().len();
        let sparse = measure(&xs, &kern, &sparse_grid);

        let dense = if dense_m > 0 {
            let grid = RectilinearGrid::fit_uniform(&xs, dense_m).expect("dense grid fit");
            Some(measure(&xs, &kern, &grid))
        } else {
            None
        };
        // What the dense grid *would* need at the sparse grid's finest
        // per-axis resolution (the m^d wall).
        let finest = GridSpec::sparse(level).size_for_dim(0);
        let dense_equiv_cells = (finest as f64).powi(d as i32);

        match &dense {
            Some(ds) => println!(
                "d={d:>2}  dense m={dense_m:<3} {:>9} cells  build {:.3}s  mvm {:.2}ms   \
                 sparse L={level} ({n_terms} terms) {:>7} pts  build {:.3}s  mvm {:.2}ms",
                ds.points,
                ds.build_s,
                ds.mvm_s * 1e3,
                sparse.points,
                sparse.build_s,
                sparse.mvm_s * 1e3
            ),
            None => println!(
                "d={d:>2}  dense INFEASIBLE ({finest}^{d} ≈ {dense_equiv_cells:.1e} cells)   \
                 sparse L={level} ({n_terms} terms) {:>7} pts  build {:.3}s  mvm {:.2}ms",
                sparse.points, sparse.build_s, sparse.mvm_s * 1e3
            ),
        }

        let dense_json = match &dense {
            Some(ds) => json_side(ds),
            None => "null".to_string(),
        };
        rows.push(format!(
            "    {{\"d\": {d}, \"n\": {n}, \"dense_m\": {dense_m}, \
             \"dense_equiv_cells\": {dense_equiv_cells:.3e}, \"dense\": {dense_json}, \
             \"sparse_level\": {level}, \"sparse_terms\": {n_terms}, \
             \"sparse\": {}}}",
            json_side(&sparse)
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"grid\",\n  \"fast\": {fast},\n  \"cases\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    let path = Path::new("results/BENCH_grid.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path).expect("bench json");
    f.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());
}
