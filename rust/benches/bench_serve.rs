//! Serving throughput bench: one-at-a-time vs batched request dispatch on
//! the same snapshot, emitting machine-readable `results/BENCH_serve.json`
//! (QPS per mode, p50/p99 latency, batch-size histogram, cache-build
//! time) so the serving perf trajectory is tracked from PR 2 onward.
//!
//! Since the fleet PR it also benches the sharded serving plane into
//! `results/BENCH_serve_fleet.json`:
//!
//! - closed-loop shard scaling — the same snapshot behind k=1 vs k=4
//!   [`ShardedModel`] shards (`fleet_vs_single_qps_ratio_k4`, gated ≥2×
//!   in CI);
//! - an **open-loop** TCP load generator against a live [`FleetServer`]:
//!   arrivals on a fixed target-QPS schedule over thousands of
//!   concurrent connections, with latency measured from the *scheduled*
//!   send time, so queueing delay is charged to the server
//!   (coordinated-omission-free p50/p99/p999).
//!
//! Run: `cargo bench --bench bench_serve` (add `-- --fast` in CI smoke;
//! fast mode keeps the connection count inside default fd limits).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::coordinator::Metrics;
use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, RequestBatcher, ServeEngine, SnapshotConfig, VarianceMode,
};
use skip_gp::serve::{FleetConfig, FleetServer, ModelRegistry, RegistryConfig, ShardedModel};
use skip_gp::util::{Rng, Timer};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct LoadStats {
    qps: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Drive `total` queries through a fresh batcher with `clients` closed-loop
/// client threads (each keeps a 64-deep pipeline outstanding).
fn run_load(
    snapshot: &ModelSnapshot,
    cfg: BatcherConfig,
    clients: usize,
    total: usize,
) -> (LoadStats, std::collections::BTreeMap<u64, u64>) {
    let engine = Arc::new(ServeEngine::new(snapshot.clone()).expect("serve engine"));
    let batcher = RequestBatcher::start(engine.clone(), cfg);
    let per_client = total / clients;
    let d = engine.dim();
    let t = Timer::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = batcher.handle();
            s.spawn(move || {
                let mut rng = Rng::new(7000 + c as u64);
                let mut q = vec![0.0; d];
                let mut pending = VecDeque::new();
                for _ in 0..per_client {
                    if pending.len() >= 64 {
                        let rx: std::sync::mpsc::Receiver<_> = pending.pop_front().unwrap();
                        rx.recv().unwrap();
                    }
                    for v in q.iter_mut() {
                        *v = rng.uniform_in(-0.9, 0.9);
                    }
                    pending.push_back(handle.submit(&q));
                }
                for rx in pending {
                    rx.recv().unwrap();
                }
            });
        }
    });
    let elapsed = t.elapsed_s();
    batcher.shutdown();
    let lat = engine.metrics.latency_snapshot("serve.request");
    let hist = engine.metrics.value_histogram("serve.batch_size");
    (
        LoadStats {
            qps: (clients * per_client) as f64 / elapsed,
            p50_us: lat.p50_s * 1e6,
            p99_us: lat.p99_s * 1e6,
        },
        hist,
    )
}

fn json_load(stats: &LoadStats) -> String {
    format!(
        "{{\"qps\": {:.1}, \"p50_us\": {:.2}, \"p99_us\": {:.2}}}",
        stats.qps, stats.p50_us, stats.p99_us
    )
}

/// Closed-loop QPS through a [`ShardedModel`] with `k` shards: the same
/// snapshot, the same one-at-a-time batcher policy, only the shard count
/// varies — so the k=4 / k=1 ratio isolates what sharding buys the
/// dispatch plane (batching amortization is measured separately above).
fn run_sharded(snap: &ModelSnapshot, k: usize, clients: usize, total: usize) -> f64 {
    let metrics = Arc::new(Metrics::new());
    let model = ShardedModel::from_snapshot(
        "bench",
        snap.clone(),
        k,
        BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
        metrics,
    )
    .expect("sharded model");
    let model = Arc::new(model);
    let per_client = total / clients;
    let d = model.dim();
    let t = Timer::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let model = model.clone();
            s.spawn(move || {
                let mut rng = Rng::new(9100 + c as u64);
                let mut q = vec![0.0; d];
                let mut pending = VecDeque::new();
                for _ in 0..per_client {
                    if pending.len() >= 64 {
                        let rx: std::sync::mpsc::Receiver<_> = pending.pop_front().unwrap();
                        rx.recv().unwrap();
                    }
                    for v in q.iter_mut() {
                        *v = rng.uniform_in(-0.9, 0.9);
                    }
                    pending.push_back(model.submit_predict(&q));
                }
                for rx in pending {
                    rx.recv().unwrap();
                }
            });
        }
    });
    let elapsed = t.elapsed_s();
    (clients * per_client) as f64 / elapsed
}

/// Exact quantile of a sorted sample (nearest-rank on `q * (len-1)`).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[(q * (sorted.len() - 1) as f64).round() as usize]
}

struct OpenConn {
    stream: TcpStream,
    wbuf: Vec<u8>,
    rbuf: Vec<u8>,
    /// Scheduled send time of each request still awaiting its reply.
    outstanding: VecDeque<Instant>,
}

/// Open-loop load against a live fleet endpoint: `total` requests arrive
/// on a fixed `target_qps` schedule, round-robined over up to
/// `conns_target` concurrent connections. Latency is measured from the
/// request's *scheduled* arrival time, not the moment the socket write
/// happened — if the server (or the generator) falls behind, the backlog
/// is charged as latency instead of silently stretching the test
/// (no coordinated omission).
///
/// Returns `(connections actually opened, achieved QPS, sorted latencies in seconds)`.
fn open_loop(
    addr: std::net::SocketAddr,
    conns_target: usize,
    target_qps: f64,
    total: usize,
    dim: usize,
) -> (usize, f64, Vec<f64>) {
    let mut conns: Vec<OpenConn> = Vec::with_capacity(conns_target);
    for _ in 0..conns_target {
        // Degrade gracefully at the fd limit: both endpoints live in this
        // process, so each connection costs two descriptors.
        let Ok(stream) = TcpStream::connect(addr) else {
            break;
        };
        stream.set_nodelay(true).ok();
        stream
            .set_nonblocking(true)
            .expect("nonblocking client socket");
        conns.push(OpenConn {
            stream,
            wbuf: Vec::new(),
            rbuf: Vec::new(),
            outstanding: VecDeque::new(),
        });
    }
    assert!(!conns.is_empty(), "open-loop generator could not open any connection to {addr}");

    // A rotating pool of pre-formatted query lines keeps the hot loop free
    // of float formatting.
    let mut rng = Rng::new(42);
    let lines: Vec<Vec<u8>> = (0..64)
        .map(|_| {
            let mut s = String::from("predict");
            for _ in 0..dim {
                s.push_str(&format!(" {:.6}", rng.uniform_in(-0.9, 0.9)));
            }
            s.push('\n');
            s.into_bytes()
        })
        .collect();

    let interval = Duration::from_secs_f64(1.0 / target_qps);
    let start = Instant::now();
    let mut next = start;
    let mut sent = 0usize;
    let mut done = 0usize;
    let mut lat = Vec::with_capacity(total);
    let mut buf = [0u8; 4096];
    while done < total {
        let now = Instant::now();
        // Arrivals stay on schedule even when earlier requests are slow:
        // that is what makes the loop "open".
        while sent < total && next <= now {
            let c = &mut conns[sent % conns.len()];
            c.wbuf.extend_from_slice(&lines[sent % lines.len()]);
            c.outstanding.push_back(next);
            sent += 1;
            next += interval;
        }
        let mut progress = false;
        for c in conns.iter_mut() {
            while !c.wbuf.is_empty() {
                match c.stream.write(&c.wbuf) {
                    Ok(0) => panic!("fleet server closed a connection mid-bench"),
                    Ok(n) => {
                        c.wbuf.drain(..n);
                        progress = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => panic!("open-loop write: {e}"),
                }
            }
            if c.outstanding.is_empty() {
                continue;
            }
            match c.stream.read(&mut buf) {
                Ok(0) => panic!("fleet server closed a connection mid-bench"),
                Ok(n) => {
                    c.rbuf.extend_from_slice(&buf[..n]);
                    let now2 = Instant::now();
                    while let Some(pos) = c.rbuf.iter().position(|&b| b == b'\n') {
                        c.rbuf.drain(..=pos);
                        let sched = c
                            .outstanding
                            .pop_front()
                            .expect("reply without a matching request");
                        lat.push(now2.saturating_duration_since(sched).as_secs_f64());
                        done += 1;
                    }
                    progress = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => panic!("open-loop read: {e}"),
            }
        }
        if !progress {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let elapsed = start.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (conns.len(), total as f64 / elapsed, lat)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let total = if fast { 20_000 } else { 100_000 };

    // A small trained model: the bench measures *serving dispatch*, so the
    // model itself stays deliberately tiny and deterministic.
    let mut rng = Rng::new(0);
    let n = 400;
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + 0.5 * (3.0 * r[1]).cos() + 0.05 * rng.normal()
        })
        .collect();
    let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.6, 1.0, 0.05));
    gp.refresh().expect("exact refresh");

    let t = Timer::start();
    let snap = ModelSnapshot::from_exact(
        &gp,
        &SnapshotConfig {
            grid: Some(skip_gp::grid::GridSpec::uniform(32)),
            variance: VarianceMode::Lanczos(32),
            ..Default::default()
        },
    )
    .expect("snapshot build");
    let cache_build_s = t.elapsed_s();
    let snapshot_bytes = snap.to_bytes().len();
    println!(
        "snapshot: {} cells, var rank {}, cache built in {:.3}s, {} bytes",
        snap.cache.total_grid(),
        snap.cache.var_rank(),
        cache_build_s,
        snapshot_bytes
    );

    let clients = 4;
    let (single, _) = run_load(
        &snap,
        BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
        clients,
        total,
    );
    println!(
        "one-at-a-time: {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        single.qps, single.p50_us, single.p99_us
    );
    let (batch8, _) = run_load(
        &snap,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        clients,
        total,
    );
    println!(
        "batched t≤8  : {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        batch8.qps, batch8.p50_us, batch8.p99_us
    );
    let (batch64, hist64) = run_load(
        &snap,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) },
        clients,
        total,
    );
    println!(
        "batched t≤64 : {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        batch64.qps, batch64.p50_us, batch64.p99_us
    );
    let speedup = batch64.qps / single.qps;
    println!("  -> batched (t=64) speedup over one-at-a-time: {speedup:.2}x");

    let hist_cells: Vec<String> = hist64
        .iter()
        .map(|(v, c)| format!("\"{v}\": {c}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"n_train\": {n},\n  \"total_requests\": {total},\n  \
         \"clients\": {clients},\n  \"cache_build_s\": {cache_build_s:.6},\n  \
         \"snapshot_bytes\": {snapshot_bytes},\n  \
         \"one_at_a_time\": {},\n  \"batched_t8\": {},\n  \"batched_t64\": {},\n  \
         \"speedup_t64\": {speedup:.3},\n  \"batch_size_histogram\": {{{}}}\n}}\n",
        json_load(&single),
        json_load(&batch8),
        json_load(&batch64),
        hist_cells.join(", ")
    );
    let path = Path::new("results/BENCH_serve.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path).expect("bench json");
    f.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());

    // ---- fleet: closed-loop shard scaling ------------------------------
    let fleet_total = if fast { 20_000 } else { 80_000 };
    let single_qps = run_sharded(&snap, 1, clients, fleet_total);
    let fleet_qps = run_sharded(&snap, 4, clients, fleet_total);
    let ratio = fleet_qps / single_qps;
    println!(
        "fleet k=1    : {single_qps:>10.0} QPS\nfleet k=4    : {fleet_qps:>10.0} QPS   \
         -> {ratio:.2}x over single shard"
    );

    // ---- fleet: open-loop tail latency over many connections ----------
    // Fast mode stays inside the default 1024-fd soft limit (both
    // endpoints are in-process, so each connection costs two fds); full
    // mode pushes to 10k connections and records how many it got.
    let (conns_target, open_total, target_qps) =
        if fast { (400, 20_000, 4000.0) } else { (10_000, 50_000, 5000.0) };
    let metrics = Arc::new(Metrics::new());
    let model = ShardedModel::from_snapshot(
        "bench",
        snap.clone(),
        4,
        BatcherConfig::default(),
        metrics.clone(),
    )
    .expect("fleet model");
    let registry = Arc::new(ModelRegistry::new(RegistryConfig::default(), metrics));
    registry.insert(model, true);
    let server = FleetServer::start(
        registry,
        FleetConfig {
            bind: "127.0.0.1:0".into(),
            max_inflight: 0, // measure queueing delay, not busy replies
            max_conns: 0,
            default_model: Some("bench".into()),
            ..Default::default()
        },
    )
    .expect("fleet server");
    let (open_conns, open_qps, lat) =
        open_loop(server.addr(), conns_target, target_qps, open_total, 2);
    let (p50_ms, p99_ms, p999_ms) = (
        pct(&lat, 0.50) * 1e3,
        pct(&lat, 0.99) * 1e3,
        pct(&lat, 0.999) * 1e3,
    );
    println!(
        "open loop    : {open_conns} conns @ target {target_qps:.0} QPS \
         (achieved {open_qps:.0})   p50 {p50_ms:.2}ms   p99 {p99_ms:.2}ms   \
         p999 {p999_ms:.2}ms"
    );
    server.shutdown();

    let fleet_json = format!(
        "{{\n  \"bench\": \"serve_fleet\",\n  \"shards_k\": 4,\n  \
         \"closed_loop_requests\": {fleet_total},\n  \
         \"single_shard_qps\": {single_qps:.1},\n  \
         \"fleet_k4_qps\": {fleet_qps:.1},\n  \
         \"fleet_vs_single_qps_ratio_k4\": {ratio:.3},\n  \
         \"open_conns\": {open_conns},\n  \
         \"open_target_qps\": {target_qps:.0},\n  \
         \"open_achieved_qps\": {open_qps:.1},\n  \
         \"open_p50_ms\": {p50_ms:.3},\n  \
         \"open_p99_ms\": {p99_ms:.3},\n  \
         \"open_p999_ms\": {p999_ms:.3}\n}}\n"
    );
    let fleet_path = Path::new("results/BENCH_serve_fleet.json");
    let mut f = std::fs::File::create(fleet_path).expect("fleet bench json");
    f.write_all(fleet_json.as_bytes()).unwrap();
    println!("wrote {}", fleet_path.display());
}
