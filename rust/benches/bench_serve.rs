//! Serving throughput bench: one-at-a-time vs batched request dispatch on
//! the same snapshot, emitting machine-readable `results/BENCH_serve.json`
//! (QPS per mode, p50/p99 latency, batch-size histogram, cache-build
//! time) so the serving perf trajectory is tracked from PR 2 onward.
//!
//! Run: `cargo bench --bench bench_serve` (add `-- --fast` in CI smoke).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::gp::{ExactGp, GpHypers};
use skip_gp::linalg::Matrix;
use skip_gp::serve::{
    BatcherConfig, ModelSnapshot, RequestBatcher, ServeEngine, SnapshotConfig, VarianceMode,
};
use skip_gp::util::{Rng, Timer};
use std::collections::VecDeque;
use std::io::Write;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

struct LoadStats {
    qps: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Drive `total` queries through a fresh batcher with `clients` closed-loop
/// client threads (each keeps a 64-deep pipeline outstanding).
fn run_load(
    snapshot: &ModelSnapshot,
    cfg: BatcherConfig,
    clients: usize,
    total: usize,
) -> (LoadStats, std::collections::BTreeMap<u64, u64>) {
    let engine = Arc::new(ServeEngine::new(snapshot.clone()).expect("serve engine"));
    let batcher = RequestBatcher::start(engine.clone(), cfg);
    let per_client = total / clients;
    let d = engine.dim();
    let t = Timer::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let handle = batcher.handle();
            s.spawn(move || {
                let mut rng = Rng::new(7000 + c as u64);
                let mut q = vec![0.0; d];
                let mut pending = VecDeque::new();
                for _ in 0..per_client {
                    if pending.len() >= 64 {
                        let rx: std::sync::mpsc::Receiver<_> = pending.pop_front().unwrap();
                        rx.recv().unwrap();
                    }
                    for v in q.iter_mut() {
                        *v = rng.uniform_in(-0.9, 0.9);
                    }
                    pending.push_back(handle.submit(&q));
                }
                for rx in pending {
                    rx.recv().unwrap();
                }
            });
        }
    });
    let elapsed = t.elapsed_s();
    batcher.shutdown();
    let lat = engine.metrics.latency_snapshot("serve.request");
    let hist = engine.metrics.value_histogram("serve.batch_size");
    (
        LoadStats {
            qps: (clients * per_client) as f64 / elapsed,
            p50_us: lat.p50_s * 1e6,
            p99_us: lat.p99_s * 1e6,
        },
        hist,
    )
}

fn json_load(stats: &LoadStats) -> String {
    format!(
        "{{\"qps\": {:.1}, \"p50_us\": {:.2}, \"p99_us\": {:.2}}}",
        stats.qps, stats.p50_us, stats.p99_us
    )
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let total = if fast { 20_000 } else { 100_000 };

    // A small trained model: the bench measures *serving dispatch*, so the
    // model itself stays deliberately tiny and deterministic.
    let mut rng = Rng::new(0);
    let n = 400;
    let xs = Matrix::from_fn(n, 2, |_, _| rng.uniform_in(-1.0, 1.0));
    let ys: Vec<f64> = (0..n)
        .map(|i| {
            let r = xs.row(i);
            (2.0 * r[0]).sin() + 0.5 * (3.0 * r[1]).cos() + 0.05 * rng.normal()
        })
        .collect();
    let mut gp = ExactGp::new(xs, ys, GpHypers::new(0.6, 1.0, 0.05));
    gp.refresh().expect("exact refresh");

    let t = Timer::start();
    let snap = ModelSnapshot::from_exact(
        &gp,
        &SnapshotConfig {
            grid: Some(skip_gp::grid::GridSpec::uniform(32)),
            variance: VarianceMode::Lanczos(32),
            ..Default::default()
        },
    )
    .expect("snapshot build");
    let cache_build_s = t.elapsed_s();
    let snapshot_bytes = snap.to_bytes().len();
    println!(
        "snapshot: {} cells, var rank {}, cache built in {:.3}s, {} bytes",
        snap.cache.total_grid(),
        snap.cache.var_rank(),
        cache_build_s,
        snapshot_bytes
    );

    let clients = 4;
    let (single, _) = run_load(
        &snap,
        BatcherConfig { max_batch: 1, max_wait: Duration::ZERO },
        clients,
        total,
    );
    println!(
        "one-at-a-time: {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        single.qps, single.p50_us, single.p99_us
    );
    let (batch8, _) = run_load(
        &snap,
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        clients,
        total,
    );
    println!(
        "batched t≤8  : {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        batch8.qps, batch8.p50_us, batch8.p99_us
    );
    let (batch64, hist64) = run_load(
        &snap,
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) },
        clients,
        total,
    );
    println!(
        "batched t≤64 : {:>10.0} QPS   p50 {:>8.1}µs   p99 {:>8.1}µs",
        batch64.qps, batch64.p50_us, batch64.p99_us
    );
    let speedup = batch64.qps / single.qps;
    println!("  -> batched (t=64) speedup over one-at-a-time: {speedup:.2}x");

    let hist_cells: Vec<String> = hist64
        .iter()
        .map(|(v, c)| format!("\"{v}\": {c}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"n_train\": {n},\n  \"total_requests\": {total},\n  \
         \"clients\": {clients},\n  \"cache_build_s\": {cache_build_s:.6},\n  \
         \"snapshot_bytes\": {snapshot_bytes},\n  \
         \"one_at_a_time\": {},\n  \"batched_t8\": {},\n  \"batched_t64\": {},\n  \
         \"speedup_t64\": {speedup:.3},\n  \"batch_size_histogram\": {{{}}}\n}}\n",
        json_load(&single),
        json_load(&batch8),
        json_load(&batch64),
        hist_cells.join(", ")
    );
    let path = Path::new("results/BENCH_serve.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path).expect("bench json");
    f.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());
}
