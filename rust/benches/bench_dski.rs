//! D-SKI derivative-observation bench: the cost of carrying `(y, ∇y)`
//! pairs through the extended interpolation operator, emitting
//! machine-readable `results/BENCH_dski.json` (gated by
//! `tools/bench_check` against `results/baselines/BENCH_dski.json`).
//!
//! Two ratios are tracked:
//!
//! - `grad_ingest_vs_refresh_speedup` — streaming one `(y, ∇y)` pair
//!   into a live D-SKI state (warm re-solve + cache patch) vs the full
//!   refresh it replaces, the closed-loop BO hot path;
//! - `dski_vs_dense_solve_speedup` — training the SKI gradient model
//!   (CG on the `W_ext (⊗K) W_extᵀ` operator) vs the dense
//!   derivative-kernel oracle (Cholesky on the n(1+d) × n(1+d) gram),
//!   the paper's headline structure-vs-dense trade at gradient scale.
//!
//! Run: `cargo bench --bench bench_dski` (add `-- --fast` in CI smoke).

#![allow(clippy::needless_range_loop)] // index-heavy numeric bench loops

use skip_gp::gp::{ExactGradGp, GpHypers, MvmGp, MvmGpConfig, MvmVariant};
use skip_gp::grid::GridSpec;
use skip_gp::linalg::Matrix;
use skip_gp::serve::VarianceMode;
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{Rng, Timer};
use std::io::Write;
use std::path::Path;

fn quantile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i] * 1e6
}

/// Smooth 2-D target with analytic gradient.
fn objective(r: &[f64]) -> (f64, [f64; 2]) {
    let y = (2.0 * r[0]).sin() + (3.0 * r[1]).cos();
    (y, [2.0 * (2.0 * r[0]).cos(), -3.0 * (3.0 * r[1]).sin()])
}

fn grad_data(n: usize, rng: &mut Rng) -> (Matrix, Vec<f64>, Matrix) {
    let d = 2;
    let mut xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    for k in 0..d {
        xs.set(0, k, -1.0);
        xs.set(1, k, 1.0);
    }
    let mut ys = Vec::with_capacity(n);
    let mut grads = Matrix::zeros(n, d);
    for i in 0..n {
        let (y, g) = objective(xs.row(i));
        ys.push(y + 0.05 * rng.normal());
        grads.set(i, 0, g[0]);
        grads.set(i, 1, g[1]);
    }
    (xs, ys, grads)
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let (n, ingests, dense_n) = if fast { (768, 24, 220) } else { (2048, 48, 400) };
    let (d, m) = (2, 32);
    let h = GpHypers::new(0.5, 1.0, 0.05);
    let mut rng = Rng::new(0);

    // --- Streaming: warm (y, ∇y) ingest vs the full refresh it avoids.
    let (xs, ys, grads) = grad_data(n, &mut rng);
    let cfg = MvmGpConfig {
        variant: MvmVariant::Kiss,
        grid: GridSpec::uniform(m),
        cg: CgConfig { max_iters: 600, tol: 1e-6, ..Default::default() },
        ..Default::default()
    };
    let scfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        log_capacity: 1 << 16,
        variance: VarianceMode::Lanczos(32),
        patch_eps: 1e-12,
        ..Default::default()
    };
    let gp = MvmGp::new_with_grads(xs, ys, grads, h, cfg.clone()).expect("D-SKI model");
    let t = Timer::start();
    let mut live = IncrementalState::from_mvm(&gp, scfg).expect("live D-SKI state");
    println!(
        "built live D-SKI model: n={n} ({} operator rows), d={d}, grid {m}x{m} ({:.3}s)",
        n * (1 + d),
        t.elapsed_s()
    );

    let mut ingest_s = Vec::with_capacity(ingests);
    let mut warm_iters = Vec::with_capacity(ingests);
    for _ in 0..ingests {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
        let (y, g) = objective(&x);
        let t = Timer::start();
        let report = live
            .ingest_with_grad(&x, y + 0.05 * rng.normal(), &g)
            .expect("grad ingest");
        ingest_s.push(t.elapsed_s());
        warm_iters.push(report.solve_iters as u64);
        assert!(report.refreshed.is_none(), "bench ingests must stay warm");
    }
    ingest_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    warm_iters.sort_unstable();
    let ingest_p50_us = quantile_us(&ingest_s, 0.50);
    let ingest_p99_us = quantile_us(&ingest_s, 0.99);
    println!(
        "(y, ∇y) ingest: p50 {ingest_p50_us:>8.1}µs   p99 {ingest_p99_us:>8.1}µs   \
         warm α-solve iters p50 {}",
        warm_iters[warm_iters.len() / 2]
    );

    let refresh_trials = 3;
    let mut refresh_s = Vec::with_capacity(refresh_trials);
    for _ in 0..refresh_trials {
        let t = Timer::start();
        live.refresh().expect("refresh");
        refresh_s.push(t.elapsed_s());
    }
    refresh_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let refresh_ms = refresh_s[refresh_trials / 2] * 1e3;
    let ingest_speedup = refresh_ms * 1e3 / ingest_p50_us.max(1e-9);
    println!(
        "full refresh: {refresh_ms:>8.2}ms (median of {refresh_trials})  \
         -> grad-ingest speedup {ingest_speedup:.2}x"
    );

    // --- Training: SKI extended-operator CG vs the dense derivative
    // oracle (the n(1+d) × n(1+d) gram + Cholesky D-SKI replaces).
    let (dxs, dys, dgrads) = grad_data(dense_n, &mut rng);
    let t = Timer::start();
    let mut ski =
        MvmGp::new_with_grads(dxs.clone(), dys.clone(), dgrads.clone(), h, cfg)
            .expect("D-SKI model");
    ski.refresh().expect("ski refresh");
    let ski_refresh_s = t.elapsed_s();
    let t = Timer::start();
    let mut dense = ExactGradGp::new(dxs, dys, dgrads, h);
    dense.refresh().expect("dense refresh");
    let dense_refresh_s = t.elapsed_s();
    let solve_speedup = dense_refresh_s / ski_refresh_s.max(1e-12);
    println!(
        "training at n={dense_n} ({} rows): ski {:.3}s vs dense {:.3}s \
         -> {solve_speedup:.2}x",
        dense_n * (1 + d),
        ski_refresh_s,
        dense_refresh_s
    );

    let json = format!(
        "{{\n  \"bench\": \"dski\",\n  \"fast\": {fast},\n  \"n\": {n},\n  \"d\": {d},\n  \
         \"grid_m\": {m},\n  \"ingests\": {ingests},\n  \
         \"grad_ingest_p50_us\": {ingest_p50_us:.2},\n  \
         \"grad_ingest_p99_us\": {ingest_p99_us:.2},\n  \
         \"refresh_ms\": {refresh_ms:.3},\n  \
         \"warm_iters_p50\": {},\n  \
         \"grad_ingest_vs_refresh_speedup\": {ingest_speedup:.3},\n  \
         \"dense_n\": {dense_n},\n  \
         \"ski_refresh_s\": {ski_refresh_s:.4},\n  \
         \"dense_refresh_s\": {dense_refresh_s:.4},\n  \
         \"dski_vs_dense_solve_speedup\": {solve_speedup:.3}\n}}\n",
        warm_iters[warm_iters.len() / 2]
    );
    let path = Path::new("results/BENCH_dski.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut out = std::fs::File::create(path).expect("bench json");
    out.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());

    assert!(
        ingest_speedup >= 2.0,
        "acceptance: a warm (y, ∇y) ingest must be ≥2x cheaper than a full \
         refresh (got {ingest_speedup:.2}x)"
    );
    assert!(
        solve_speedup >= 1.0,
        "acceptance: D-SKI training must not be slower than the dense \
         derivative-kernel oracle (got {solve_speedup:.2}x)"
    );
}
