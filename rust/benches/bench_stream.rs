//! Streaming-ingestion bench: single-point (and small-batch) online
//! ingest vs a full refresh on the same model, emitting machine-readable
//! `results/BENCH_stream.json` (ingest p50/p99, refresh time, the
//! ingest-vs-refresh speedup, and warm-vs-cold solver iterations) so the
//! online-update perf trajectory is tracked — and gated by
//! `tools/bench_check` — from this PR onward.
//!
//! Run: `cargo bench --bench bench_stream` (add `-- --fast` in CI smoke).

#![allow(clippy::needless_range_loop)] // index-heavy numeric bench loops

use skip_gp::gp::GpHypers;
use skip_gp::grid::Grid1d;
use skip_gp::linalg::Matrix;
use skip_gp::serve::VarianceMode;
use skip_gp::solvers::CgConfig;
use skip_gp::stream::{IncrementalState, StreamConfig};
use skip_gp::util::{Rng, Timer};
use std::io::Write;
use std::path::Path;

fn quantile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i] * 1e6
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let (n, ingests) = if fast { (1024, 32) } else { (4096, 64) };
    let d = 2;
    let m = 32;

    let mut rng = Rng::new(0);
    let mut xs = Matrix::from_fn(n, d, |_, _| rng.uniform_in(-1.0, 1.0));
    for k in 0..d {
        xs.set(0, k, -1.0);
        xs.set(1, k, 1.0);
    }
    let f = |r: &[f64]| (2.0 * r[0]).sin() + (3.0 * r[1]).cos();
    let ys: Vec<f64> = (0..n).map(|i| f(xs.row(i)) + 0.05 * rng.normal()).collect();
    let axes = vec![
        Grid1d::fit(-1.0, 1.0, m).unwrap(),
        Grid1d::fit(-1.0, 1.0, m).unwrap(),
    ];
    let cg = CgConfig { max_iters: 500, tol: 1e-8, ..Default::default() };
    // Realistic serving config, but with the drift/policy triggers out of
    // the way so the measured ingests all take the warm incremental path
    // (the refresh they are compared against rebuilds the variance too).
    let cfg = StreamConfig {
        refresh_every: 0,
        var_drift_budget: usize::MAX,
        error_z: 0.0,
        log_capacity: 1 << 16,
        variance: VarianceMode::Lanczos(64),
        patch_eps: 1e-12,
        ..Default::default()
    };

    let t = Timer::start();
    let mut live =
        IncrementalState::new(xs, ys, GpHypers::new(0.5, 1.0, 0.05), axes, cg, cfg)
            .expect("live state");
    println!(
        "built live model: n={n}, d={d}, grid {m}x{m}, var rank 64 ({:.3}s)",
        t.elapsed_s()
    );

    // Single-point ingest latency (the streaming hot path).
    let mut ingest_s = Vec::with_capacity(ingests);
    let mut warm_iters = Vec::with_capacity(ingests);
    for _ in 0..ingests {
        let x: Vec<f64> = (0..d).map(|_| rng.uniform_in(-0.9, 0.9)).collect();
        let y = f(&x) + 0.05 * rng.normal();
        let t = Timer::start();
        let report = live.ingest(&x, y).expect("ingest");
        ingest_s.push(t.elapsed_s());
        warm_iters.push(report.solve_iters as u64);
        assert!(report.refreshed.is_none(), "bench ingests must stay warm");
    }
    ingest_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    warm_iters.sort_unstable();
    let ingest_p50_us = quantile_us(&ingest_s, 0.50);
    let ingest_p99_us = quantile_us(&ingest_s, 0.99);
    println!(
        "single-point ingest: p50 {ingest_p50_us:>8.1}µs   p99 {ingest_p99_us:>8.1}µs   \
         warm α-solve iters p50 {}",
        warm_iters[warm_iters.len() / 2]
    );

    // Small-batch ingest (the batcher's coalesced path): per-point cost.
    let batch = 8;
    let bx = Matrix::from_fn(batch, d, |_, _| rng.uniform_in(-0.9, 0.9));
    let by: Vec<f64> = (0..batch).map(|i| f(bx.row(i)) + 0.05 * rng.normal()).collect();
    let t = Timer::start();
    live.ingest_block(&bx, &by).expect("batch ingest");
    let batch_point_us = t.elapsed_s() * 1e6 / batch as f64;
    println!("batched t={batch} ingest: {batch_point_us:>8.1}µs/point");

    // Full refresh: rebuild operator + preconditioner + cold α solve +
    // full cache (mean scatter + variance factor) — what every ingest
    // would cost without the incremental path.
    let refresh_trials = 3;
    let mut refresh_s = Vec::with_capacity(refresh_trials);
    for _ in 0..refresh_trials {
        let t = Timer::start();
        live.refresh().expect("refresh");
        refresh_s.push(t.elapsed_s());
    }
    refresh_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let refresh_ms = refresh_s[refresh_trials / 2] * 1e3;
    println!("full refresh: {refresh_ms:>8.2}ms (median of {refresh_trials})");

    let ingest_median_us = quantile_us(&ingest_s, 0.50);
    let speedup = refresh_ms * 1e3 / ingest_median_us.max(1e-9);
    println!("  -> single-point ingest speedup over full refresh: {speedup:.2}x");

    let json = format!(
        "{{\n  \"bench\": \"stream\",\n  \"fast\": {fast},\n  \"n\": {n},\n  \"d\": {d},\n  \
         \"grid_m\": {m},\n  \"ingests\": {ingests},\n  \
         \"ingest_p50_us\": {ingest_p50_us:.2},\n  \"ingest_p99_us\": {ingest_p99_us:.2},\n  \
         \"batch8_point_us\": {batch_point_us:.2},\n  \"refresh_ms\": {refresh_ms:.3},\n  \
         \"warm_iters_p50\": {},\n  \
         \"speedup_single_vs_refresh\": {speedup:.3}\n}}\n",
        warm_iters[warm_iters.len() / 2]
    );
    let path = Path::new("results/BENCH_stream.json");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut out = std::fs::File::create(path).expect("bench json");
    out.write_all(json.as_bytes()).unwrap();
    println!("wrote {}", path.display());

    assert!(
        speedup >= 5.0,
        "acceptance: single-point ingest must be ≥5x cheaper than a full \
         refresh (got {speedup:.2}x)"
    );
}
