//! Micro-benchmarks for the hot operators (criterion is unavailable in
//! this offline environment, so this is a custom `harness = false` bench
//! using median-of-N wall-clock timing).
//!
//! Covers every layer of the MVM stack plus the PJRT artifact path:
//!   toeplitz_mvm        — O(m log m) grid-kernel multiply (SKI inner)
//!   ski_mvm             — O(n + m log m) 1-D SKI operator
//!   kiss_mvm            — Kronecker-grid operator (d = 3)
//!   lemma31_native      — the O(r²n) Hadamard contraction, Rust
//!   lemma31_matmat_serial/fused — t=8 block contraction, column loop vs
//!                         the fused single-pass batched engine
//!   lemma31_pjrt        — same contraction through the AOT artifact
//!   skip_build          — full merge-tree construction (d = 8)
//!   skip_mvm            — root MVM after caching (Corollary 3.4)
//!   skip_matmat_serial/fused — t=8 root block MVM, serial vs batched
//!   cg_solve            — 30-iteration CG on the SKIP operator
//!   cg_loop_8rhs / block_cg_8rhs — t=8 solves, serial loop vs block-CG
//!                         (the ≥2× acceptance case of the batched engine)
//!   gridspace_n*        — per-CG-iteration cost, grid space vs data
//!                         space, across n ∈ {10⁴, 10⁵, 10⁶} (emits
//!                         results/BENCH_gridspace.json; the flat-in-n
//!                         ratio is gated by tools/bench_check)
//!   precision_mvm_*     — f64 vs f32 operator storage on the n = 10⁵
//!                         KISS MVM (emits results/BENCH_precision.json;
//!                         the mixed-vs-f64 MVM speedup is gated by
//!                         tools/bench_check)
//!
//! Run: `cargo bench` (add `-- --fast` for a quick pass).

#![allow(clippy::needless_range_loop)] // index-heavy numeric test/bench loops

use skip_gp::data::gaussian_cloud;
use skip_gp::kernels::{ProductKernel, Stationary1d};
use skip_gp::linalg::{Matrix, SymToeplitz};
use skip_gp::operators::lowrank::{
    hadamard_pair_matmat_native, hadamard_pair_matvec_native, ContractionBackend,
    LanczosFactor,
};
use skip_gp::operators::{
    matmat_via_matvec, ArcOp, KroneckerSkiOp, LinearOp, LinearOpF32, SkiOp,
    SkipComponent, SkipOp,
};
use skip_gp::operators::AffineOp;
use skip_gp::runtime::PjrtBackend;
use skip_gp::solvers::{
    block_cg_solve, build_preconditioner, cg_solve, cg_solve_with,
    grid_cg_solve_with_wty, CgConfig, GridSystem, PrecondSpec, Preconditioner,
};
use skip_gp::util::{bench_median_s, rel_err, Rng, Timer};
use std::io::Write;
use std::path::Path;
use std::sync::Arc;

struct Bench {
    rows: Vec<(String, f64, String)>,
    min_iters: usize,
    min_time: f64,
}

impl Bench {
    fn run(&mut self, name: &str, note: &str, f: impl FnMut()) {
        self.timed(name, note, f);
    }

    /// Like [`Bench::run`] but returns the median seconds, so paired
    /// serial-vs-batched cases can report their speedup.
    fn timed(&mut self, name: &str, note: &str, mut f: impl FnMut()) -> f64 {
        let med = bench_median_s(self.min_iters, self.min_time, &mut f);
        println!("{name:<18} {:>12.3} µs   {note}", med * 1e6);
        self.rows.push((name.to_string(), med, note.to_string()));
        med
    }

    fn write_csv(&self, path: &Path) {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path).expect("bench csv");
        writeln!(f, "bench,median_s,note").unwrap();
        for (n, t, note) in &self.rows {
            writeln!(f, "{n},{t},{note}").unwrap();
        }
        println!("wrote {}", path.display());
    }
}

fn random_factor(n: usize, r: usize, seed: u64) -> LanczosFactor {
    let mut rng = Rng::new(seed);
    let q = Matrix::from_fn(n, r, |_, _| rng.normal());
    let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
    t.symmetrize();
    LanczosFactor { q, t }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut b = Bench {
        rows: Vec::new(),
        min_iters: if fast { 3 } else { 10 },
        min_time: if fast { 0.05 } else { 0.3 },
    };
    let mut rng = Rng::new(0);

    // --- Toeplitz MVM (SKI's K_UU multiply), m = 1024.
    {
        let kern = Stationary1d::rbf(0.5);
        let t = SymToeplitz::new(kern.toeplitz_column(1024, 0.01));
        let v = rng.normal_vec(1024);
        b.run("toeplitz_mvm", "m=1024", || {
            std::hint::black_box(t.matvec(&v));
        });
    }

    // --- 1-D SKI MVM, n = 4096, m = 512.
    {
        let xs = gaussian_cloud(4096, 1, 1);
        let kern = Stationary1d::rbf(0.7);
        let ski = SkiOp::new(&xs.col(0), &kern, 512).unwrap();
        let v = rng.normal_vec(4096);
        b.run("ski_mvm", "n=4096 m=512", || {
            std::hint::black_box(ski.matvec(&v));
        });
    }

    // --- KISS-GP MVM, n = 2048, d = 3, m = 32 (grid 32³ = 32768).
    {
        let xs = gaussian_cloud(2048, 3, 2);
        let kern = ProductKernel::rbf(3, 1.0, 1.0);
        let op = KroneckerSkiOp::new(&xs, &kern, 32).unwrap();
        let v = rng.normal_vec(2048);
        b.run("kiss_mvm", "n=2048 d=3 m=32", || {
            std::hint::black_box(op.matvec(&v));
        });
    }

    // --- Lemma 3.1 contraction, native, n = 2048, r = 32.
    let fa = random_factor(2048, 32, 3);
    let fb = random_factor(2048, 32, 4);
    let v2048 = rng.normal_vec(2048);
    b.run("lemma31_native", "n=2048 r=32", || {
        std::hint::black_box(hadamard_pair_matvec_native(&fa, &fb, &v2048));
    });

    // --- Lemma 3.1 block contraction: serial column loop vs fused.
    {
        let block = Matrix::from_fn(2048, 8, |_, _| rng.normal());
        let serial = b.timed("lemma31_mm_serial", "n=2048 r=32 t=8 (col loop)", || {
            let mut out = Matrix::zeros(2048, 8);
            for j in 0..8 {
                out.set_col(j, &hadamard_pair_matvec_native(&fa, &fb, &block.col(j)));
            }
            std::hint::black_box(out);
        });
        let fused = b.timed("lemma31_mm_fused", "n=2048 r=32 t=8 (one pass)", || {
            std::hint::black_box(hadamard_pair_matmat_native(&fa, &fb, &block));
        });
        println!("  -> fused block contraction speedup: {:.2}x", serial / fused);
    }

    // --- Same contraction through the PJRT artifact (if built).
    if Path::new("artifacts/manifest.json").exists() {
        let backend = PjrtBackend::load(Path::new("artifacts")).expect("artifacts");
        b.run("lemma31_pjrt", "n=2048 r=32 (AOT artifact)", || {
            std::hint::black_box(backend.hadamard_pair_matvec(&fa, &fb, &v2048));
        });
        let (pjrt, native) = backend.call_counts();
        assert!(pjrt > 0 && native == 0, "pjrt bench fell back to native");
    } else {
        println!("lemma31_pjrt       skipped (run `make artifacts`)");
    }

    // --- SKIP merge-tree build + cached MVM, n = 2048, d = 8, r = 20.
    {
        let n = 2048;
        let d = 8;
        let xs = gaussian_cloud(n, d, 5);
        let kern = ProductKernel::rbf(d, 1.6, 1.0);
        let skis: Vec<SkiOp> = (0..d)
            .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], 128).unwrap())
            .collect();
        b.run("skip_build", "n=2048 d=8 r=20", || {
            let comps: Vec<SkipComponent> = skis
                .iter()
                .map(|s| SkipComponent::Op(s as &dyn LinearOp))
                .collect();
            let mut r = Rng::new(6);
            std::hint::black_box(SkipOp::build_native(comps, 20, &mut r));
        });
        let comps: Vec<SkipComponent> = skis
            .iter()
            .map(|s| SkipComponent::Op(s as &dyn LinearOp))
            .collect();
        let mut r6 = Rng::new(6);
        let skip = SkipOp::build_native(comps, 20, &mut r6);
        let v = rng.normal_vec(n);
        b.run("skip_mvm", "n=2048 d=8 r=20 (cached)", || {
            std::hint::black_box(skip.matvec(&v));
        });

        // --- Batched root MVM: serial column loop vs the fused matmat.
        let t_rhs = 8;
        let block = Matrix::from_fn(n, t_rhs, |_, _| rng.normal());
        let mm_serial = b.timed("skip_mm_serial", "n=2048 t=8 (col loop)", || {
            std::hint::black_box(matmat_via_matvec(&skip, &block));
        });
        let mm_fused = b.timed("skip_mm_fused", "n=2048 t=8 (batched)", || {
            std::hint::black_box(skip.matmat(&block));
        });
        println!("  -> skip matmat speedup: {:.2}x", mm_serial / mm_fused);

        // --- CG solve on the SKIP operator.
        let shifted = skip_gp::operators::AffineOp {
            inner: Box::new(skip),
            scale: 1.0,
            shift: 0.1,
        };
        let y = rng.normal_vec(n);
        b.run("cg_solve", "n=2048 30 iters", || {
            std::hint::black_box(cg_solve(
                &shifted,
                &y,
                CgConfig { max_iters: 30, tol: 1e-10, ..Default::default() },
            ));
        });

        // --- The batched-engine acceptance case: t = 8 simultaneous
        // solves against the SKIP-backed K̂, serial CG loop vs block-CG.
        let rhs = Matrix::from_fn(n, t_rhs, |_, _| rng.normal());
        let cfg = CgConfig { max_iters: 30, tol: 1e-10, ..Default::default() };
        let serial_s = b.timed("cg_loop_8rhs", "n=2048 t=8 30 iters (serial)", || {
            for j in 0..t_rhs {
                std::hint::black_box(cg_solve(&shifted, &rhs.col(j), cfg));
            }
        });
        let block_s = b.timed("block_cg_8rhs", "n=2048 t=8 30 iters (batched)", || {
            std::hint::black_box(block_cg_solve(&shifted, &rhs, cfg));
        });
        println!("  -> block-CG speedup over serial loop: {:.2}x", serial_s / block_s);
        // Correctness cross-check: block solution matches the serial one.
        let block_sol = block_cg_solve(&shifted, &rhs, cfg);
        let mut worst = 0.0f64;
        for j in 0..t_rhs {
            let single = cg_solve(&shifted, &rhs.col(j), cfg);
            worst = worst.max(rel_err(&block_sol.x.col(j), &single.x));
        }
        println!("  -> block vs serial max column rel err: {worst:.2e}");
    }

    // --- Preconditioned CG: the n=4096 1-D SKI case. Small σ_n² makes
    // K̂ = K_SKI + σ_n²I ill-conditioned, which is where the rank-k
    // pivoted-Cholesky preconditioner collapses the iteration count
    // (Yadav et al. 2021). Paired plain-vs-preconditioned runs, recorded
    // machine-readably in results/BENCH_precond.json (uploaded from CI).
    {
        let n = 4096;
        let xs = gaussian_cloud(n, 1, 7);
        let kern = Stationary1d::rbf(0.7);
        let ski = SkiOp::new(&xs.col(0), &kern, 512).expect("bench SKI grid");
        let sn2 = 1e-3;
        let khat = AffineOp { inner: Box::new(ski), scale: 1.0, shift: sn2 };
        let y = rng.normal_vec(n);
        let tol = 1e-6;
        let cfg = CgConfig { max_iters: 2000, tol, ..Default::default() };

        let plain = cg_solve(&khat, &y, cfg);
        assert!(plain.converged, "plain CG must converge for the paired case");
        let cg_s = b.timed("cg_plain_n4096", &format!("SKI tol=1e-6 ({} iters)", plain.iters), || {
            std::hint::black_box(cg_solve(&khat, &y, cfg));
        });

        let rank = 50;
        let setup_s = b.timed("pcg_setup_rank50", "pivoted-Cholesky build", || {
            std::hint::black_box(build_preconditioner(
                &khat,
                Some(sn2),
                PrecondSpec::PivChol { rank },
            ));
        });
        let pre = build_preconditioner(&khat, Some(sn2), PrecondSpec::PivChol { rank });
        let pcg = cg_solve_with(&khat, &y, pre.as_ref(), None, cfg);
        assert!(pcg.converged, "PCG must converge for the paired case");
        let pcg_s = b.timed("pcg_rank50_n4096", &format!("SKI tol=1e-6 ({} iters)", pcg.iters), || {
            std::hint::black_box(cg_solve_with(&khat, &y, pre.as_ref(), None, cfg));
        });
        let jac = build_preconditioner(&khat, Some(sn2), PrecondSpec::Jacobi);
        let jacobi = cg_solve_with(&khat, &y, jac.as_ref(), None, cfg);

        // Solution agreement, judged on *tight* solves so the comparison
        // measures the preconditioner (zero accuracy change), not the
        // stopping point: both paths run to 1e-12 and must coincide.
        let tight = CgConfig { max_iters: 4000, tol: 1e-12, ..Default::default() };
        let xa = cg_solve(&khat, &y, tight);
        let xb = cg_solve_with(&khat, &y, pre.as_ref(), None, tight);
        // An unconverged tight solve would make `agreement` measure
        // truncation error, not preconditioner equivalence.
        assert!(
            xa.converged && xb.converged,
            "tight agreement solves must converge (cg {:.1e}, pcg {:.1e})",
            xa.rel_residual,
            xb.rel_residual
        );
        let agreement = rel_err(&xa.x, &xb.x);

        let iters_ratio = plain.iters as f64 / pcg.iters.max(1) as f64;
        println!(
            "  -> precond rank:{rank} iteration reduction: {iters_ratio:.1}x \
             ({} -> {} iters at tol {tol:.0e}), agreement {agreement:.2e}",
            plain.iters, pcg.iters
        );
        let json = format!(
            "{{\n  \"bench\": \"precond\",\n  \"n\": {n},\n  \"operator\": \"ski_m512_rbf\",\n  \
             \"noise\": {sn2},\n  \"tol\": {tol},\n  \"precond\": \"rank:{rank}\",\n  \
             \"setup_rank\": {setup_rank},\n  \"cg_iters\": {cg_iters},\n  \
             \"pcg_iters\": {pcg_iters},\n  \"jacobi_iters\": {jacobi_iters},\n  \
             \"iters_ratio\": {iters_ratio:.3},\n  \"cg_s\": {cg_s:.6},\n  \
             \"pcg_s\": {pcg_s:.6},\n  \"pcg_setup_s\": {setup_s:.6},\n  \
             \"solve_speedup\": {speedup:.3},\n  \"agreement_rel_err\": {agreement:.3e}\n}}\n",
            setup_rank = pre.cost().rank,
            cg_iters = plain.iters,
            pcg_iters = pcg.iters,
            jacobi_iters = jacobi.iters,
            speedup = cg_s / pcg_s,
        );
        let path = Path::new("results/BENCH_precond.json");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, json).expect("bench json");
        println!("wrote {}", path.display());
    }

    // --- Grid-space iteration engine: per-CG-iteration cost vs n.
    // The grid-space normal equations iterate on the m grid points only
    // (one Kronecker–Toeplitz apply + one banded WᵀW apply), so the cost
    // of an iteration must be *flat* in n, while data-space CG walks all
    // n stencil rows twice per iteration. Measured by differencing two
    // iteration budgets on the same system — tol = 0 never converges, so
    // each solve runs exactly max_iters, and the difference cancels the
    // per-solve O(n) work (Wᵀy projection, α back-projection) that both
    // budgets share. Recorded machine-readably in
    // results/BENCH_gridspace.json; bench_check gates the flatness ratio
    // against results/baselines with a two-sided band.
    {
        let d = 2;
        let m = 64; // 64×64 grid: M = 4096, band width 7² = 49
        let (sf2, sn2) = (1.0, 0.1);
        let ns: [usize; 3] = [10_000, 100_000, 1_000_000];
        let (hi, lo) = (15usize, 5usize);
        // Grid solves are milliseconds even at the top n — always take a
        // min-of-3. Data solves at n = 10⁶ are the expensive part; one
        // reading suffices under --fast.
        let grid_reps = 3;
        let data_reps = if fast { 1 } else { 3 };
        let mut grid_per_iter_us = Vec::with_capacity(ns.len());
        let mut data_per_iter_us = Vec::with_capacity(ns.len());
        for &n in &ns {
            let xs = gaussian_cloud(n, d, 11);
            let mut ry = Rng::new(12);
            let y: Vec<f64> = (0..n).map(|_| ry.normal()).collect();
            let kern = ProductKernel::rbf(d, 0.5, 1.0);
            let op = Arc::new(KroneckerSkiOp::new(&xs, &kern, m).expect("bench grid"));
            let sys = GridSystem::new(vec![(1.0, op.clone())], sf2, sn2)
                .expect("bench grid system");
            let wty = sys.wt(&y);
            let data_view =
                AffineOp { inner: Box::new(ArcOp(op)), scale: sf2, shift: sn2 };
            let min_s = |reps: usize, f: &mut dyn FnMut()| -> f64 {
                let mut best = f64::INFINITY;
                for _ in 0..reps {
                    let t = Timer::start();
                    f();
                    best = best.min(t.elapsed_s());
                }
                best
            };
            let grid_s = |iters: usize| -> f64 {
                let cfg = CgConfig { max_iters: iters, tol: 0.0, ..Default::default() };
                min_s(grid_reps, &mut || {
                    std::hint::black_box(grid_cg_solve_with_wty(
                        &sys, &y, &wty, None, cfg,
                    ));
                })
            };
            let data_s = |iters: usize| -> f64 {
                let cfg = CgConfig { max_iters: iters, tol: 0.0, ..Default::default() };
                min_s(data_reps, &mut || {
                    std::hint::black_box(cg_solve(&data_view, &y, cfg));
                })
            };
            let span = (hi - lo) as f64;
            let g_us = ((grid_s(hi) - grid_s(lo)) / span * 1e6).max(1e-3);
            let d_us = ((data_s(hi) - data_s(lo)) / span * 1e6).max(1e-3);
            println!(
                "gridspace_n{n:<7} grid {g_us:>10.1} µs/iter   data {d_us:>10.1} µs/iter \
                 (m={m}x{m})",
            );
            b.rows.push((
                format!("gridspace_n{n}"),
                g_us / 1e6,
                format!("grid-space µs/iter, d={d} m={m}x{m}"),
            ));
            grid_per_iter_us.push(g_us);
            data_per_iter_us.push(d_us);
        }
        let ratio = grid_per_iter_us[2] / grid_per_iter_us[0];
        let data_growth = data_per_iter_us[2] / data_per_iter_us[0];
        println!(
            "  -> grid-space per-iteration cost, 10^6 vs 10^4 points: {ratio:.2}x \
             (data space grows {data_growth:.1}x)"
        );
        let cases: Vec<String> = ns
            .iter()
            .zip(grid_per_iter_us.iter().zip(&data_per_iter_us))
            .map(|(n, (g, dt))| {
                format!(
                    "{{\"n\": {n}, \"grid_per_iter_us\": {g:.2}, \
                     \"data_per_iter_us\": {dt:.2}}}"
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"gridspace\",\n  \"fast\": {fast},\n  \"d\": {d},\n  \
             \"grid_m\": {m},\n  \"grid_cells\": {cells},\n  \"iters_hi\": {hi},\n  \
             \"iters_lo\": {lo},\n  \"cases\": [\n    {cases}\n  ],\n  \
             \"per_iter_us_ratio_1e6_vs_1e4\": {ratio:.3},\n  \
             \"data_per_iter_growth_1e6_vs_1e4\": {data_growth:.3}\n}}\n",
            cells = m * m,
            cases = cases.join(",\n    "),
        );
        let path = Path::new("results/BENCH_gridspace.json");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, json).expect("bench json");
        println!("wrote {}", path.display());
        assert!(
            ratio <= 1.5,
            "acceptance: grid-space per-iteration cost must be flat in n \
             (10^6 vs 10^4 ratio {ratio:.2}x > 1.5x)"
        );
    }

    // --- Mixed-precision MVM substrate: the same n = 10⁵ KISS operator
    // applied with f64 storage vs the f32 view (f32 stencil weights, f32
    // Toeplitz spectra, f32 FFT butterflies). The MVM is memory-bound on
    // the stencil gather/scatter, so halving the operand width should buy
    // ~1.5–2× — the `mvm_speedup_f32_vs_f64` field is gated ≥ 1.3× by
    // tools/bench_check against results/baselines/BENCH_precision.json.
    // The f32 view is built once outside the timed region, matching how
    // `refined_cg_solve` amortizes one `as_f32()` across a whole solve.
    {
        let n = 100_000;
        let d = 2;
        let m = 64;
        let xs = gaussian_cloud(n, d, 21);
        let kern = ProductKernel::rbf(d, 0.5, 1.0);
        let op = KroneckerSkiOp::new(&xs, &kern, m).expect("bench precision grid");
        let view = op.f32_view();
        let mut rv = Rng::new(22);
        let v: Vec<f64> = (0..n).map(|_| rv.normal()).collect();
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();

        // Correctness first: the f32 path must track f64 elementwise to
        // f32 grade before its timing means anything.
        let want = op.matvec(&v);
        let got32 = view.matvec_f32(&v32);
        let scale = want.iter().fold(1.0f64, |a, x| a.max(x.abs()));
        let worst = want
            .iter()
            .zip(&got32)
            .fold(0.0f64, |a, (w, g)| a.max((w - *g as f64).abs()));
        assert!(
            worst <= 1e-3 * scale,
            "f32 MVM drifted from f64: {worst:.3e} vs scale {scale:.3e}"
        );

        let f64_s = b.timed("precision_mvm_f64", &format!("n={n} d={d} m={m}x{m}"), || {
            std::hint::black_box(op.matvec(&v));
        });
        let f32_s =
            b.timed("precision_mvm_f32", &format!("n={n} d={d} m={m}x{m} (f32 view)"), || {
                std::hint::black_box(view.matvec_f32(&v32));
            });
        let speedup = f64_s / f32_s;
        println!(
            "  -> f32 operator-storage MVM speedup: {speedup:.2}x \
             (max |f32 − f64| = {worst:.2e})"
        );
        let json = format!(
            "{{\n  \"bench\": \"precision\",\n  \"fast\": {fast},\n  \"n\": {n},\n  \
             \"d\": {d},\n  \"grid_m\": {m},\n  \"f64_mvm_us\": {f64_us:.2},\n  \
             \"f32_mvm_us\": {f32_us:.2},\n  \
             \"mvm_speedup_f32_vs_f64\": {speedup:.3},\n  \
             \"max_abs_err_vs_f64\": {worst:.3e}\n}}\n",
            f64_us = f64_s * 1e6,
            f32_us = f32_s * 1e6,
        );
        let path = Path::new("results/BENCH_precision.json");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, json).expect("bench json");
        println!("wrote {}", path.display());
    }

    b.write_csv(Path::new("results/bench_micro.csv"));
}
