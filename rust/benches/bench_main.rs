//! Micro-benchmarks for the hot operators (criterion is unavailable in
//! this offline environment, so this is a custom `harness = false` bench
//! using median-of-N wall-clock timing).
//!
//! Covers every layer of the MVM stack plus the PJRT artifact path:
//!   toeplitz_mvm        — O(m log m) grid-kernel multiply (SKI inner)
//!   ski_mvm             — O(n + m log m) 1-D SKI operator
//!   kiss_mvm            — Kronecker-grid operator (d = 3)
//!   lemma31_native      — the O(r²n) Hadamard contraction, Rust
//!   lemma31_pjrt        — same contraction through the AOT artifact
//!   skip_build          — full merge-tree construction (d = 8)
//!   skip_mvm            — root MVM after caching (Corollary 3.4)
//!   cg_solve            — 30-iteration CG on the SKIP operator
//!
//! Run: `cargo bench` (add `-- --fast` for a quick pass).

use skip_gp::data::gaussian_cloud;
use skip_gp::kernels::{ProductKernel, Stationary1d};
use skip_gp::linalg::{Matrix, SymToeplitz};
use skip_gp::operators::lowrank::{
    hadamard_pair_matvec_native, ContractionBackend, LanczosFactor,
};
use skip_gp::operators::{KroneckerSkiOp, LinearOp, SkiOp, SkipComponent, SkipOp};
use skip_gp::runtime::PjrtBackend;
use skip_gp::solvers::{cg_solve, CgConfig};
use skip_gp::util::{bench_median_s, Rng};
use std::io::Write;
use std::path::Path;

struct Bench {
    rows: Vec<(String, f64, String)>,
    min_iters: usize,
    min_time: f64,
}

impl Bench {
    fn run(&mut self, name: &str, note: &str, mut f: impl FnMut()) {
        let med = bench_median_s(self.min_iters, self.min_time, &mut f);
        println!("{name:<18} {:>12.3} µs   {note}", med * 1e6);
        self.rows.push((name.to_string(), med, note.to_string()));
    }

    fn write_csv(&self, path: &Path) {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path).expect("bench csv");
        writeln!(f, "bench,median_s,note").unwrap();
        for (n, t, note) in &self.rows {
            writeln!(f, "{n},{t},{note}").unwrap();
        }
        println!("wrote {}", path.display());
    }
}

fn random_factor(n: usize, r: usize, seed: u64) -> LanczosFactor {
    let mut rng = Rng::new(seed);
    let q = Matrix::from_fn(n, r, |_, _| rng.normal());
    let mut t = Matrix::from_fn(r, r, |_, _| rng.normal());
    t.symmetrize();
    LanczosFactor { q, t }
}

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let mut b = Bench {
        rows: Vec::new(),
        min_iters: if fast { 3 } else { 10 },
        min_time: if fast { 0.05 } else { 0.3 },
    };
    let mut rng = Rng::new(0);

    // --- Toeplitz MVM (SKI's K_UU multiply), m = 1024.
    {
        let kern = Stationary1d::rbf(0.5);
        let t = SymToeplitz::new(kern.toeplitz_column(1024, 0.01));
        let v = rng.normal_vec(1024);
        b.run("toeplitz_mvm", "m=1024", || {
            std::hint::black_box(t.matvec(&v));
        });
    }

    // --- 1-D SKI MVM, n = 4096, m = 512.
    {
        let xs = gaussian_cloud(4096, 1, 1);
        let kern = Stationary1d::rbf(0.7);
        let ski = SkiOp::new(&xs.col(0), &kern, 512);
        let v = rng.normal_vec(4096);
        b.run("ski_mvm", "n=4096 m=512", || {
            std::hint::black_box(ski.matvec(&v));
        });
    }

    // --- KISS-GP MVM, n = 2048, d = 3, m = 32 (grid 32³ = 32768).
    {
        let xs = gaussian_cloud(2048, 3, 2);
        let kern = ProductKernel::rbf(3, 1.0, 1.0);
        let op = KroneckerSkiOp::new(&xs, &kern, 32);
        let v = rng.normal_vec(2048);
        b.run("kiss_mvm", "n=2048 d=3 m=32", || {
            std::hint::black_box(op.matvec(&v));
        });
    }

    // --- Lemma 3.1 contraction, native, n = 2048, r = 32.
    let fa = random_factor(2048, 32, 3);
    let fb = random_factor(2048, 32, 4);
    let v2048 = rng.normal_vec(2048);
    b.run("lemma31_native", "n=2048 r=32", || {
        std::hint::black_box(hadamard_pair_matvec_native(&fa, &fb, &v2048));
    });

    // --- Same contraction through the PJRT artifact (if built).
    if Path::new("artifacts/manifest.json").exists() {
        let backend = PjrtBackend::load(Path::new("artifacts")).expect("artifacts");
        b.run("lemma31_pjrt", "n=2048 r=32 (AOT artifact)", || {
            std::hint::black_box(backend.hadamard_pair_matvec(&fa, &fb, &v2048));
        });
        let (pjrt, native) = backend.call_counts();
        assert!(pjrt > 0 && native == 0, "pjrt bench fell back to native");
    } else {
        println!("lemma31_pjrt       skipped (run `make artifacts`)");
    }

    // --- SKIP merge-tree build + cached MVM, n = 2048, d = 8, r = 20.
    {
        let n = 2048;
        let d = 8;
        let xs = gaussian_cloud(n, d, 5);
        let kern = ProductKernel::rbf(d, 1.6, 1.0);
        let skis: Vec<SkiOp> = (0..d)
            .map(|k| SkiOp::new(&xs.col(k), &kern.factors[k], 128))
            .collect();
        b.run("skip_build", "n=2048 d=8 r=20", || {
            let comps: Vec<SkipComponent> = skis
                .iter()
                .map(|s| SkipComponent::Op(s as &dyn LinearOp))
                .collect();
            let mut r = Rng::new(6);
            std::hint::black_box(SkipOp::build_native(comps, 20, &mut r));
        });
        let comps: Vec<SkipComponent> = skis
            .iter()
            .map(|s| SkipComponent::Op(s as &dyn LinearOp))
            .collect();
        let mut r6 = Rng::new(6);
        let skip = SkipOp::build_native(comps, 20, &mut r6);
        let v = rng.normal_vec(n);
        b.run("skip_mvm", "n=2048 d=8 r=20 (cached)", || {
            std::hint::black_box(skip.matvec(&v));
        });
        // --- CG solve on the SKIP operator.
        let shifted = skip_gp::operators::AffineOp {
            inner: Box::new(skip),
            scale: 1.0,
            shift: 0.1,
        };
        let y = rng.normal_vec(n);
        b.run("cg_solve", "n=2048 30 iters", || {
            std::hint::black_box(cg_solve(
                &shifted,
                &y,
                CgConfig { max_iters: 30, tol: 1e-10 },
            ));
        });
    }

    b.write_csv(Path::new("results/bench_micro.csv"));
}
