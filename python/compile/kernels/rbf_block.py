"""Layer-1 Pallas kernel: RBF cross-covariance tile + predictive-mean
contraction.

The predictive mean (paper Eq. 1) is `mu* = K_{*X} alpha` with
`K_{*X}[i,j] = sf2 * exp(-||x*_i - x_j||^2 / (2 ell^2))`. The kernel tiles
the (n_test × n_train) implicit matrix into (block_t × block_n) VMEM tiles,
computes each tile with a rank-d squared-distance expansion, and
accumulates the partial `tile @ alpha_blk` products — the n×n matrix is
never materialized in HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 64
DEFAULT_BLOCK_N = 256


def _rbf_mean_kernel(xt_ref, xs_ref, alpha_ref, params_ref, o_ref):
    """Accumulate o_blk += sf2 * exp(-d2/(2 ell^2)) @ alpha_blk."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xt = xt_ref[...]          # (bt, d)
    xs = xs_ref[...]          # (bn, d)
    alpha = alpha_ref[...]    # (bn,)
    ell = params_ref[0]
    sf2 = params_ref[1]
    # ||a-b||^2 = |a|^2 + |b|^2 - 2ab — the 2ab term is an MXU matmul.
    at2 = jnp.sum(xt * xt, axis=1)[:, None]
    bs2 = jnp.sum(xs * xs, axis=1)[None, :]
    cross = xt @ xs.T
    d2 = at2 + bs2 - 2.0 * cross
    k = sf2 * jnp.exp(-0.5 * d2 / (ell * ell))
    o_ref[...] += k @ alpha


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "interpret"))
def rbf_cross_mean(xtest, xtrain, alpha, params, *, block_t=DEFAULT_BLOCK_T,
                   block_n=DEFAULT_BLOCK_N, interpret=True):
    """mu = sf2 * K_rbf(xtest, xtrain) @ alpha, tiled in VMEM.

    params = jnp.array([ell, sf2]). AOT-lowered to
    `artifacts/rbf_mean_*.hlo.txt` for the Rust predict path.
    """
    nt, d = xtest.shape
    ns, d2 = xtrain.shape
    assert d == d2 and alpha.shape == (ns,)
    block_t = min(block_t, nt)
    block_n = min(block_n, ns)
    assert nt % block_t == 0, f"nt={nt} % block_t={block_t}"
    assert ns % block_n == 0, f"ns={ns} % block_n={block_n}"
    grid = (nt // block_t, ns // block_n)
    return pl.pallas_call(
        _rbf_mean_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((nt,), xtest.dtype),
        interpret=interpret,
    )(xtest, xtrain, alpha, params)
