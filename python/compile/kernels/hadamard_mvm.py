"""Layer-1 Pallas kernels for the Lemma-3.1 Hadamard-product MVM.

The contraction `(Q1 T1 Q1^T ∘ Q2 T2 Q2^T) v` factors into three stages:

    S = Q1^T D_v Q2          (r1 × r2 cross-moment, reduction over n)
    M = T1 S T2              (r × r, tiny — plain jnp between the kernels)
    out_i = q1_i · (M q2_i)  (row-wise bilinear diagonal over n)

Stages 1 and 3 stream the n-dimension and are written as Pallas kernels
tiled over n-blocks; the r×r dimensions stay resident.

Hardware adaptation (paper implements this in CUDA/GPyTorch): on TPU each
n-block of Q1/Q2 is staged HBM→VMEM by the BlockSpec, and the two
(block_n × r)·(r × r) products in stage 3 map directly onto the MXU. Here
we run interpret=True (CPU PJRT cannot execute Mosaic custom-calls), so
the kernels serve as the *specification* of the schedule; VMEM/MXU
estimates for the chosen block shapes live in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# n-block size: 256 rows × r≤64 cols × 8 B ≈ 128 KiB per operand block,
# comfortably inside a 16 MiB VMEM budget with double-buffering.
DEFAULT_BLOCK_N = 256


def _s_accum_kernel(q1_ref, q2_ref, v_ref, s_ref):
    """Accumulate S += Q1_blk^T (v_blk ⊙ Q2_blk) across the n-grid."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q1 = q1_ref[...]
    q2 = q2_ref[...]
    v = v_ref[...]
    s_ref[...] += q1.T @ (v[:, None] * q2)


def hadamard_s(q1, q2, v, *, block_n=DEFAULT_BLOCK_N, interpret=True):
    """S = Q1^T D_v Q2 via a Pallas reduction over n-blocks."""
    n, r1 = q1.shape
    _, r2 = q2.shape
    assert q2.shape[0] == n and v.shape == (n,)
    block_n = min(block_n, n)
    assert n % block_n == 0, f"n={n} must be divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _s_accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, r1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, r2), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        # Every grid step maps to the same output block → accumulation.
        out_specs=pl.BlockSpec((r1, r2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r1, r2), q1.dtype),
        interpret=interpret,
    )(q1, q2, v)


def _bilinear_diag_kernel(q1_ref, m_ref, q2_ref, o_ref):
    """o_blk[i] = q1_blk[i] · (M @ q2_blk[i]) — two MXU matmuls + reduce."""
    q1 = q1_ref[...]
    q2 = q2_ref[...]
    m = m_ref[...]
    # (block_n, r2) @ (r2, r1) → row-wise dot with q1: Δ(Q1 M Q2^T).
    p = q2 @ m.T
    o_ref[...] = jnp.sum(q1 * p, axis=1)


def bilinear_diag(q1, m, q2, *, block_n=DEFAULT_BLOCK_N, interpret=True):
    """out[i] = q1[i] @ M @ q2[i]^T via a Pallas map over n-blocks."""
    n, r1 = q1.shape
    _, r2 = q2.shape
    assert m.shape == (r1, r2) and q2.shape[0] == n
    block_n = min(block_n, n)
    assert n % block_n == 0, f"n={n} must be divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _bilinear_diag_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, r1), lambda i: (i, 0)),
            pl.BlockSpec((r1, r2), lambda i: (0, 0)),
            pl.BlockSpec((block_n, r2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), q1.dtype),
        interpret=interpret,
    )(q1, m, q2)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def hadamard_pair_mvm(q1, t1, q2, t2, v, *, block_n=DEFAULT_BLOCK_N,
                      interpret=True):
    """Full Lemma-3.1 MVM `(Q1T1Q1^T ∘ Q2T2Q2^T) v` in O(r^2 n).

    This is the function AOT-lowered to `artifacts/hadamard_mvm_*.hlo.txt`
    and executed from the Rust hot path via PJRT.
    """
    s = hadamard_s(q1, q2, v, block_n=block_n, interpret=interpret)
    # M = T1 S T2^T: the identity is (A ∘ B) v = Δ(A D_v B^T) with
    # B^T = Q2 T2^T Q2^T — the transpose matters for non-symmetric T2
    # (Lanczos T is symmetric, but the kernel contract is general).
    m = t1 @ s @ t2.T  # r×r — negligible; fused by XLA with stage 3
    return bilinear_diag(q1, m, q2, block_n=block_n, interpret=interpret)
