"""Pure-jnp oracles for the Pallas kernels (Layer-1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here
written in straightforward jax.numpy. pytest (python/tests/) asserts
allclose between kernel and oracle across shape/dtype sweeps; the same
oracles also pin down the semantics the Rust-native implementations in
rust/src/operators/lowrank.rs must match.
"""

import jax.numpy as jnp


def hadamard_s_ref(q1, q2, v):
    """S = Q1^T D_v Q2  — the (r1, r2) cross-moment of Lemma 3.1."""
    return q1.T @ (v[:, None] * q2)


def bilinear_diag_ref(q1, m, q2):
    """out[i] = q1[i, :] @ M @ q2[i, :]^T  — the Δ(Q1 M Q2^T) diagonal."""
    return jnp.einsum("ip,pq,iq->i", q1, m, q2)


def hadamard_pair_mvm_ref(q1, t1, q2, t2, v):
    """Full Lemma-3.1 product-kernel MVM:

        (Q1 T1 Q1^T ∘ Q2 T2 Q2^T) v = Δ(Q1 T1 Q1^T D_v Q2 T2 Q2^T).

    Evaluated here *densely* (O(n^2)) as the semantic oracle.
    """
    a = q1 @ t1 @ q1.T
    b = q2 @ t2 @ q2.T
    return (a * b) @ v


def hadamard_pair_mvm_fast_ref(q1, t1, q2, t2, v):
    """The O(r^2 n) algebra the kernels implement (still pure jnp).

    Note the T2 transpose: (A ∘ B) v = Δ(A D_v B^T), B^T = Q2 T2^T Q2^T.
    """
    s = hadamard_s_ref(q1, q2, v)
    m = t1 @ s @ t2.T
    return bilinear_diag_ref(q1, m, q2)


def rbf_block_ref(x, y, ell):
    """Pairwise RBF kernel block: K[i, j] = exp(-||x_i - y_j||^2 / (2 ell^2)).

    x: (bx, d), y: (by, d) -> (bx, by).
    """
    sq = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.exp(-0.5 * sq / (ell * ell))


def rbf_cross_mean_ref(xtest, xtrain, alpha, ell, sf2):
    """Predictive-mean contraction: mu = sf2 * K(xtest, xtrain) @ alpha."""
    return sf2 * rbf_block_ref(xtest, xtrain, ell) @ alpha
