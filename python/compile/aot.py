"""AOT pipeline: lower the Layer-2 graphs to HLO text artifacts.

Run once at build time (`make artifacts`); the Rust runtime
(`rust/src/runtime/`) loads the artifacts through
`HloModuleProto::from_text_file` and executes them on the PJRT CPU client.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Besides one `.hlo.txt` per (graph, shape) pair, a `manifest.json` records
every artifact's operand shapes so the Rust registry can route requests to
a compatible executable (zero-padding n and r preserves exactness).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# (n, r) shape points for the Hadamard-pair MVM artifact. n must be a
# multiple of the kernel block (256); r covers the ranks the harness uses.
HADAMARD_SHAPES = [
    (1024, 16),
    (2048, 32),
    (4096, 32),
]

# (n_test, n_train, d) for the predictive-mean artifact.
PREDICT_SHAPES = [
    (256, 2048, 4),
    (512, 4096, 8),
]

# Chain length for the Corollary-3.4 chained-MVM artifact.
CHAIN_STEPS = 8
CHAIN_SHAPES = [(2048, 32)]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float64):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_hadamard(n: int, r: int) -> str:
    lowered = jax.jit(model.skip_mvm).lower(
        spec((n, r)), spec((r, r)), spec((n, r)), spec((r, r)), spec((n,))
    )
    return to_hlo_text(lowered)


def lower_predict(nt: int, ns: int, d: int) -> str:
    lowered = jax.jit(model.predict_mean).lower(
        spec((nt, d)), spec((ns, d)), spec((ns,)), spec((2,))
    )
    return to_hlo_text(lowered)


def lower_chain(n: int, r: int, steps: int) -> str:
    fn = lambda q1, t1, q2, t2, v: model.skip_mvm_chain(  # noqa: E731
        q1, t1, q2, t2, v, steps=steps
    )
    lowered = jax.jit(fn).lower(
        spec((n, r)), spec((r, r)), spec((n, r)), spec((r, r)), spec((n,))
    )
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    # Kept for Makefile compatibility: `--out path` names the sentinel file.
    parser.add_argument("--out", default=None)
    args = parser.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "dtype": "f64", "artifacts": []}

    for n, r in HADAMARD_SHAPES:
        name = f"hadamard_mvm_n{n}_r{r}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_hadamard(n, r))
        manifest["artifacts"].append(
            {"name": name, "op": "hadamard_mvm", "n": n, "r": r,
             "file": os.path.basename(path)}
        )
        print(f"wrote {path}")

    for nt, ns, d in PREDICT_SHAPES:
        name = f"rbf_mean_t{nt}_n{ns}_d{d}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_predict(nt, ns, d))
        manifest["artifacts"].append(
            {"name": name, "op": "rbf_mean", "n_test": nt, "n_train": ns,
             "d": d, "file": os.path.basename(path)}
        )
        print(f"wrote {path}")

    for n, r in CHAIN_SHAPES:
        name = f"hadamard_chain{CHAIN_STEPS}_n{n}_r{r}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(lower_chain(n, r, CHAIN_STEPS))
        manifest["artifacts"].append(
            {"name": name, "op": "hadamard_chain", "n": n, "r": r,
             "steps": CHAIN_STEPS, "file": os.path.basename(path)}
        )
        print(f"wrote {path}")

    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")

    # Sentinel for the Makefile dependency (also doubles as a build stamp).
    if args.out:
        with open(args.out, "w") as f:
            f.write(json.dumps({"artifacts": len(manifest["artifacts"])}))
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
