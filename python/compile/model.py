"""Layer-2 JAX compute graphs for the SKIP hot path.

These functions compose the Layer-1 Pallas kernels into the jitted graphs
that `aot.py` lowers to HLO text. They are the *only* things the Rust
runtime executes through PJRT; Python never runs on the request path.

Graphs
------
- ``skip_mvm``: the Lemma-3.1 Hadamard-pair MVM — SKIP's per-iteration
  hot-spot inside CG / Lanczos (O(r² n)).
- ``predict_mean``: the exact RBF cross-covariance predictive-mean
  contraction μ* = σ_f² K(X*, X) α (paper Eq. 1).
- ``skip_mvm_chain``: p chained MVMs with the same cached decomposition —
  demonstrates Corollary 3.4 (subsequent MVMs reuse Q/T) as a single
  fused graph for the benchmark harness.
"""

import jax

from .kernels.hadamard_mvm import hadamard_pair_mvm
from .kernels.rbf_block import rbf_cross_mean

# All artifacts are lowered in f64: the Rust side works in f64 end-to-end
# and CPU PJRT has no MXU-driven reason to prefer bf16.
jax.config.update("jax_enable_x64", True)


def skip_mvm(q1, t1, q2, t2, v):
    """(Q1 T1 Q1ᵀ ∘ Q2 T2 Q2ᵀ) v — root MVM of the SKIP merge tree."""
    return (hadamard_pair_mvm(q1, t1, q2, t2, v),)


def predict_mean(xtest, xtrain, alpha, params):
    """μ* = σ_f² K_rbf(X*, X) α, params = [ell, sf2]."""
    return (rbf_cross_mean(xtest, xtrain, alpha, params),)


def skip_mvm_chain(q1, t1, q2, t2, v, steps: int = 4):
    """Apply the Hadamard-pair operator `steps` times: K(K(...K v)).

    Exercises Corollary 3.4: the decomposition (q1,t1,q2,t2) is built once
    and reused across MVMs; only the vector changes. Lowered as one fused
    graph so XLA can keep Q1/Q2 resident.
    """

    def body(carry, _):
        out = hadamard_pair_mvm(q1, t1, q2, t2, carry)
        return out, None

    final, _ = jax.lax.scan(body, v, None, length=steps)
    return (final,)
