"""Layer-2 model graphs: shape checks, numerical checks, AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape))


class TestSkipMvmGraph:
    def test_matches_oracle(self):
        rng = np.random.default_rng(0)
        n, r = 512, 16
        q1, q2 = rand(rng, n, r), rand(rng, n, r)
        t1, t2 = rand(rng, r, r), rand(rng, r, r)
        v = rand(rng, n)
        (got,) = model.skip_mvm(q1, t1, q2, t2, v)
        want = ref.hadamard_pair_mvm_ref(q1, t1, q2, t2, v)
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)

    def test_chain_is_repeated_application(self):
        rng = np.random.default_rng(1)
        n, r, steps = 256, 8, 3
        q1, q2 = rand(rng, n, r), rand(rng, n, r)
        # Scale down so the power iteration stays bounded.
        t1, t2 = 0.1 * rand(rng, r, r), 0.1 * rand(rng, r, r)
        v = rand(rng, n)
        (got,) = model.skip_mvm_chain(q1, t1, q2, t2, v, steps=steps)
        want = v
        for _ in range(steps):
            want = ref.hadamard_pair_mvm_fast_ref(q1, t1, q2, t2, want)
        np.testing.assert_allclose(got, want, rtol=1e-7, atol=1e-9)


class TestPredictMeanGraph:
    def test_matches_oracle(self):
        rng = np.random.default_rng(2)
        nt, ns, d = 64, 256, 4
        xt, xs = rand(rng, nt, d), rand(rng, ns, d)
        alpha = rand(rng, ns)
        params = jnp.array([0.9, 1.4])
        (got,) = model.predict_mean(xt, xs, alpha, params)
        want = ref.rbf_cross_mean_ref(xt, xs, alpha, 0.9, 1.4)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


class TestAotLowering:
    def test_hadamard_hlo_text_parses(self):
        text = aot.lower_hadamard(256, 8)
        assert "HloModule" in text
        # f64 tensors of the right shapes appear in the entry computation.
        assert "f64[256,8]" in text
        assert "f64[256]" in text

    def test_predict_hlo_text(self):
        text = aot.lower_predict(64, 256, 3)
        assert "HloModule" in text
        assert "f64[64,3]" in text

    def test_chain_hlo_text(self):
        text = aot.lower_chain(256, 8, 4)
        assert "HloModule" in text

    def test_shapes_registered_in_manifest_tables(self):
        # The (n, r) grid aot.py lowers must satisfy the kernel block
        # divisibility contract.
        for n, r in aot.HADAMARD_SHAPES:
            assert n % 256 == 0, (n, r)
        for nt, ns, d in aot.PREDICT_SHAPES:
            assert nt % 64 == 0 and ns % 256 == 0
