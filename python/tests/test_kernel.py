"""Pallas kernels vs pure-jnp oracles — the core Layer-1 correctness signal.

Hypothesis sweeps shapes and dtypes; fixed-seed numpy data keeps runs
reproducible. All kernels run interpret=True (CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hadamard_mvm as hk
from compile.kernels import rbf_block as rk
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- hadamard

class TestHadamardS:
    @pytest.mark.parametrize("n,r1,r2,block", [
        (256, 8, 8, 256),
        (512, 16, 8, 256),
        (1024, 32, 32, 256),
        (512, 4, 12, 128),
    ])
    def test_matches_ref(self, n, r1, r2, block):
        rng = np.random.default_rng(0)
        q1, q2, v = rand(rng, n, r1), rand(rng, n, r2), rand(rng, n)
        got = hk.hadamard_s(q1, q2, v, block_n=block)
        want = ref.hadamard_s_ref(q1, q2, v)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)

    def test_accumulation_over_many_blocks(self):
        # Exercises the grid-accumulation path with 8 blocks.
        rng = np.random.default_rng(1)
        n, r = 2048, 16
        q1, q2, v = rand(rng, n, r), rand(rng, n, r), rand(rng, n)
        got = hk.hadamard_s(q1, q2, v, block_n=256)
        want = ref.hadamard_s_ref(q1, q2, v)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


class TestBilinearDiag:
    @pytest.mark.parametrize("n,r1,r2", [(256, 8, 8), (512, 32, 16), (768, 5, 7)])
    def test_matches_ref(self, n, r1, r2):
        rng = np.random.default_rng(2)
        q1, q2 = rand(rng, n, r1), rand(rng, n, r2)
        m = rand(rng, r1, r2)
        got = hk.bilinear_diag(q1, m, q2, block_n=256)
        want = ref.bilinear_diag_ref(q1, m, q2)
        np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


class TestHadamardPairMvm:
    @pytest.mark.parametrize("n,r", [(256, 4), (512, 16), (1024, 32)])
    def test_matches_dense_oracle(self, n, r):
        rng = np.random.default_rng(3)
        q1, q2 = rand(rng, n, r), rand(rng, n, r)
        t1, t2 = rand(rng, r, r), rand(rng, r, r)
        v = rand(rng, n)
        got = hk.hadamard_pair_mvm(q1, t1, q2, t2, v)
        want = ref.hadamard_pair_mvm_ref(q1, t1, q2, t2, v)
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)

    def test_fast_ref_equals_dense_ref(self):
        # Internal consistency of the two oracles (Lemma 3.1 itself).
        rng = np.random.default_rng(4)
        n, r = 300, 6
        q1, q2 = rand(rng, n, r), rand(rng, n, r)
        t1, t2 = rand(rng, r, r), rand(rng, r, r)
        v = rand(rng, n)
        a = ref.hadamard_pair_mvm_ref(q1, t1, q2, t2, v)
        b = ref.hadamard_pair_mvm_fast_ref(q1, t1, q2, t2, v)
        np.testing.assert_allclose(a, b, rtol=1e-9, atol=1e-9)

    @settings(max_examples=15, deadline=None)
    @given(
        n_blocks=st.integers(min_value=1, max_value=6),
        r1=st.integers(min_value=1, max_value=40),
        r2=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_shape_sweep(self, n_blocks, r1, r2, seed):
        rng = np.random.default_rng(seed)
        n = 128 * n_blocks
        q1, q2 = rand(rng, n, r1), rand(rng, n, r2)
        t1, t2 = rand(rng, r1, r1), rand(rng, r2, r2)
        v = rand(rng, n)
        got = hk.hadamard_pair_mvm(q1, t1, q2, t2, v, block_n=128)
        want = ref.hadamard_pair_mvm_fast_ref(q1, t1, q2, t2, v)
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)

    def test_float32_dtype(self):
        rng = np.random.default_rng(5)
        n, r = 256, 8
        q1 = rand(rng, n, r, dtype=np.float32)
        q2 = rand(rng, n, r, dtype=np.float32)
        t1 = rand(rng, r, r, dtype=np.float32)
        t2 = rand(rng, r, r, dtype=np.float32)
        v = rand(rng, n, dtype=np.float32)
        got = hk.hadamard_pair_mvm(q1, t1, q2, t2, v)
        assert got.dtype == jnp.float32
        want = ref.hadamard_pair_mvm_fast_ref(q1, t1, q2, t2, v)
        # f32 accumulations over n=256 with O(10³)-magnitude outputs:
        # compare at f32-appropriate tolerance.
        np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)

    def test_symmetric_psd_factors_give_symmetric_operator(self):
        # ⟨Ku, w⟩ = ⟨u, Kw⟩ for symmetric T — property the GP stack relies on.
        rng = np.random.default_rng(6)
        n, r = 256, 10
        q1, q2 = rand(rng, n, r), rand(rng, n, r)
        t1 = rand(rng, r, r)
        t1 = (t1 + t1.T) / 2
        t2 = rand(rng, r, r)
        t2 = (t2 + t2.T) / 2
        u, w = rand(rng, n), rand(rng, n)
        ku = hk.hadamard_pair_mvm(q1, t1, q2, t2, u)
        kw = hk.hadamard_pair_mvm(q1, t1, q2, t2, w)
        np.testing.assert_allclose(jnp.dot(ku, w), jnp.dot(u, kw), rtol=1e-8)


# --------------------------------------------------------------- rbf block

class TestRbfCrossMean:
    @pytest.mark.parametrize("nt,ns,d", [(64, 256, 2), (128, 512, 4), (64, 512, 9)])
    def test_matches_ref(self, nt, ns, d):
        rng = np.random.default_rng(7)
        xt, xs = rand(rng, nt, d), rand(rng, ns, d)
        alpha = rand(rng, ns)
        ell, sf2 = 0.7, 1.3
        params = jnp.array([ell, sf2])
        got = rk.rbf_cross_mean(xt, xs, alpha, params, block_t=64, block_n=256)
        want = ref.rbf_cross_mean_ref(xt, xs, alpha, ell, sf2)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(
        bt=st.integers(min_value=1, max_value=3),
        bn=st.integers(min_value=1, max_value=4),
        d=st.integers(min_value=1, max_value=12),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, bt, bn, d, seed):
        rng = np.random.default_rng(seed)
        nt, ns = 32 * bt, 128 * bn
        xt, xs = rand(rng, nt, d), rand(rng, ns, d)
        alpha = rand(rng, ns)
        params = jnp.array([1.1, 0.9])
        got = rk.rbf_cross_mean(xt, xs, alpha, params, block_t=32, block_n=128)
        want = ref.rbf_cross_mean_ref(xt, xs, alpha, 1.1, 0.9)
        np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-8)

    def test_kernel_value_at_zero_distance(self):
        # Single coincident point: mean = sf2 * alpha.
        xt = jnp.zeros((32, 3))
        xs = jnp.zeros((128, 3))
        alpha = jnp.zeros(128).at[0].set(2.0)
        params = jnp.array([1.0, 1.5])
        got = rk.rbf_cross_mean(xt, xs, alpha, params, block_t=32, block_n=128)
        np.testing.assert_allclose(got, jnp.full(32, 3.0), rtol=1e-12)
